//! Configuration system: presets, optimizer specs, and a small
//! `key = value` config-file format with CLI overrides.
//!
//! Presets mirror `python/compile/model.py::PRESETS` exactly — the
//! manifest emitted by `aot.py` is the authority at runtime, and
//! `runtime::Manifest::check_preset` cross-validates the two.

pub mod presets;

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

pub use presets::{ModelPreset, PRESETS};

/// Which optimizer drives the eligible (attention/MLP) matrices.
/// Non-eligible parameters always use full Adam, matching the paper.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OptSpec {
    Adam,
    /// Gradient Wavelet Transform at `level`.
    Gwt { level: usize },
    /// GaLore with rank = min_dim / rank_denom, SVD every `update_gap`.
    Galore { rank_denom: usize },
    /// APOLLO: random projection, rank = min_dim / rank_denom.
    Apollo { rank_denom: usize },
    /// LoRA-style adapter training (rank = min_dim / rank_denom).
    Lora { rank_denom: usize },
    /// Adam-mini: one shared second-moment scalar per parameter block.
    AdamMini,
    /// MUON: momentum + Newton–Schulz orthogonalization.
    Muon,
    /// Block-quantized 8-bit Adam.
    Adam8bit,
    /// SGD with momentum (memory floor reference).
    SgdM,
}

impl OptSpec {
    /// Parse `adam`, `gwt-2`, `galore-1/4`, `apollo-1/8`, `lora-1/4`,
    /// `adam-mini`, `muon`, `adam8bit`, `sgdm`.
    pub fn parse(s: &str) -> Result<OptSpec> {
        let s = s.trim().to_lowercase();
        if let Some(rest) = s.strip_prefix("gwt-") {
            return Ok(OptSpec::Gwt { level: rest.parse().context("gwt level")? });
        }
        for (prefix, ctor) in [
            ("galore-1/", OptSpec::Galore { rank_denom: 0 }),
            ("apollo-1/", OptSpec::Apollo { rank_denom: 0 }),
            ("lora-1/", OptSpec::Lora { rank_denom: 0 }),
        ] {
            if let Some(rest) = s.strip_prefix(prefix) {
                let d: usize = rest.parse().context("rank denom")?;
                if d == 0 {
                    bail!("rank denominator must be positive");
                }
                return Ok(match ctor {
                    OptSpec::Galore { .. } => OptSpec::Galore { rank_denom: d },
                    OptSpec::Apollo { .. } => OptSpec::Apollo { rank_denom: d },
                    _ => OptSpec::Lora { rank_denom: d },
                });
            }
        }
        Ok(match s.as_str() {
            "adam" => OptSpec::Adam,
            "adam-mini" | "adammini" => OptSpec::AdamMini,
            "muon" => OptSpec::Muon,
            "adam8bit" | "8bit-adam" => OptSpec::Adam8bit,
            "sgdm" | "sgd-m" | "sgd" => OptSpec::SgdM,
            other => bail!("unknown optimizer spec '{other}'"),
        })
    }

    pub fn label(&self) -> String {
        match self {
            OptSpec::Adam => "Adam".into(),
            OptSpec::Gwt { level } => format!("GWT-{level}"),
            OptSpec::Galore { rank_denom } => format!("GaLore-1/{rank_denom}"),
            OptSpec::Apollo { rank_denom } => format!("APOLLO-1/{rank_denom}"),
            OptSpec::Lora { rank_denom } => format!("LoRA-1/{rank_denom}"),
            OptSpec::AdamMini => "Adam-mini".into(),
            OptSpec::Muon => "MUON".into(),
            OptSpec::Adam8bit => "8bit-Adam".into(),
            OptSpec::SgdM => "SGD-M".into(),
        }
    }

    /// Memory-model counterpart for the accountant.
    pub fn memory_method(&self) -> crate::memory::Method {
        use crate::memory::Method;
        match *self {
            OptSpec::Adam => Method::Adam,
            OptSpec::Gwt { level } => Method::Gwt { level },
            OptSpec::Galore { rank_denom } => Method::Galore { rank_denom },
            OptSpec::Apollo { rank_denom } => Method::Apollo { rank_denom },
            OptSpec::Lora { rank_denom } => Method::Lora { rank_denom },
            OptSpec::AdamMini => Method::Adam, // states differ in count, not span
            OptSpec::Muon => Method::Muon,
            OptSpec::Adam8bit => Method::Adam8bit,
            OptSpec::SgdM => Method::SgdM,
        }
    }
}

/// Full training-run configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub preset: String,
    pub optimizer: OptSpec,
    pub lr: f32,
    /// GWT/GaLore scale factor α (module-wise lr = lr·α on eligible).
    pub alpha: f32,
    pub steps: usize,
    pub warmup_frac: f32,
    pub seed: u64,
    /// Gradient accumulation microbatches per optimizer step.
    pub grad_accum: usize,
    /// Data-parallel worker count (thread-simulated GPUs).
    pub dp_workers: usize,
    /// Parallel step-engine worker threads for the optimizer bank /
    /// GWT row sharding (`pool::scoped_chunks_mut`). `1` = serial,
    /// `0` = auto-detect from the host, capped by the preset's
    /// `max_step_workers`. Output is bit-identical at every setting
    /// (fixed chunk boundaries, no cross-item reductions).
    pub threads: usize,
    /// Norm-growth limiter threshold γ (0 disables, paper: 1.01).
    pub nl_gamma: f32,
    /// Apply module-wise lr (α on eligible modules) — paper default.
    pub modulewise_lr: bool,
    pub eval_every: usize,
    /// Betas / eps shared across Adam-family methods.
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// GaLore subspace refresh interval (paper: 200).
    pub galore_update_gap: usize,
    pub artifacts_dir: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            preset: "nano".into(),
            optimizer: OptSpec::Gwt { level: 2 },
            lr: 0.01,
            alpha: 0.25,
            steps: 200,
            warmup_frac: 0.1,
            seed: 0,
            grad_accum: 1,
            dp_workers: 1,
            threads: 1,
            nl_gamma: 1.01,
            modulewise_lr: true,
            eval_every: 50,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-6,
            galore_update_gap: 50,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl TrainConfig {
    /// Apply one `key=value` assignment (config file line or CLI -s).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let v = value.trim();
        match key.trim() {
            "preset" => self.preset = v.into(),
            "optimizer" => self.optimizer = OptSpec::parse(v)?,
            "lr" => self.lr = v.parse().context("lr")?,
            "alpha" => self.alpha = v.parse().context("alpha")?,
            "steps" => self.steps = v.parse().context("steps")?,
            "warmup_frac" => self.warmup_frac = v.parse().context("warmup_frac")?,
            "seed" => self.seed = v.parse().context("seed")?,
            "grad_accum" => self.grad_accum = v.parse().context("grad_accum")?,
            "dp_workers" => self.dp_workers = v.parse().context("dp_workers")?,
            "threads" => self.threads = v.parse().context("threads")?,
            "nl_gamma" => self.nl_gamma = v.parse().context("nl_gamma")?,
            "modulewise_lr" => self.modulewise_lr = parse_bool(v)?,
            "eval_every" => self.eval_every = v.parse().context("eval_every")?,
            "beta1" => self.beta1 = v.parse().context("beta1")?,
            "beta2" => self.beta2 = v.parse().context("beta2")?,
            "eps" => self.eps = v.parse().context("eps")?,
            "galore_update_gap" => {
                self.galore_update_gap = v.parse().context("galore_update_gap")?
            }
            "artifacts_dir" => self.artifacts_dir = v.into(),
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Parse a config file: `key = value` lines, `#` comments,
    /// `[section]` headers are ignored (cosmetic grouping only).
    pub fn from_file(path: &str) -> Result<TrainConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let mut cfg = TrainConfig::default();
        cfg.apply_text(&text)?;
        Ok(cfg)
    }

    pub fn apply_text(&mut self, text: &str) -> Result<()> {
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() || (line.starts_with('[') && line.ends_with(']')) {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key=value", lineno + 1))?;
            self.set(k, v)
                .with_context(|| format!("line {}", lineno + 1))?;
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if !PRESETS.iter().any(|p| p.name == self.preset) {
            bail!(
                "unknown preset '{}' (known: {})",
                self.preset,
                PRESETS.iter().map(|p| p.name).collect::<Vec<_>>().join(", ")
            );
        }
        if self.lr <= 0.0 || self.steps == 0 || self.grad_accum == 0 || self.dp_workers == 0 {
            bail!("lr/steps/grad_accum/dp_workers must be positive");
        }
        if !(0.0..=1.0).contains(&self.warmup_frac) {
            bail!("warmup_frac must be in [0,1]");
        }
        if let OptSpec::Gwt { level } = self.optimizer {
            let p = presets::find(&self.preset)?;
            for (m, n) in p.gwt_shapes() {
                if n % (1usize << level) != 0 {
                    bail!("preset {} shape {m}x{n} incompatible with GWT level {level}", p.name);
                }
            }
        }
        Ok(())
    }

    /// Resolve the step-engine worker count: `0` auto-detects from
    /// the host's available parallelism, capped by the preset's
    /// useful maximum (one worker per parameter tensor); an explicit
    /// positive value is honored as-is.
    pub fn resolve_threads(&self) -> usize {
        if self.threads > 0 {
            return self.threads;
        }
        let hw = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let cap = presets::find(&self.preset)
            .map(|p| p.max_step_workers())
            .unwrap_or(hw);
        hw.min(cap).max(1)
    }

    pub fn summary(&self) -> BTreeMap<String, String> {
        let mut m = BTreeMap::new();
        m.insert("preset".into(), self.preset.clone());
        m.insert("optimizer".into(), self.optimizer.label());
        m.insert("lr".into(), format!("{}", self.lr));
        m.insert("alpha".into(), format!("{}", self.alpha));
        m.insert("steps".into(), format!("{}", self.steps));
        m.insert("dp_workers".into(), format!("{}", self.dp_workers));
        m.insert("threads".into(), format!("{}", self.threads));
        m.insert("nl_gamma".into(), format!("{}", self.nl_gamma));
        m
    }
}

fn parse_bool(v: &str) -> Result<bool> {
    match v {
        "true" | "1" | "yes" | "on" => Ok(true),
        "false" | "0" | "no" | "off" => Ok(false),
        other => bail!("not a bool: '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_opt_specs() {
        assert_eq!(OptSpec::parse("adam").unwrap(), OptSpec::Adam);
        assert_eq!(OptSpec::parse("GWT-3").unwrap(), OptSpec::Gwt { level: 3 });
        assert_eq!(
            OptSpec::parse("galore-1/4").unwrap(),
            OptSpec::Galore { rank_denom: 4 }
        );
        assert_eq!(
            OptSpec::parse("apollo-1/8").unwrap(),
            OptSpec::Apollo { rank_denom: 8 }
        );
        assert_eq!(OptSpec::parse("muon").unwrap(), OptSpec::Muon);
        assert_eq!(OptSpec::parse("adam-mini").unwrap(), OptSpec::AdamMini);
        assert!(OptSpec::parse("magic").is_err());
        assert!(OptSpec::parse("galore-1/0").is_err());
        assert!(OptSpec::parse("gwt-x").is_err());
    }

    #[test]
    fn labels_roundtrip_via_parse() {
        for spec in [
            OptSpec::Adam,
            OptSpec::Gwt { level: 2 },
            OptSpec::Galore { rank_denom: 8 },
            OptSpec::Apollo { rank_denom: 4 },
            OptSpec::Muon,
        ] {
            assert_eq!(OptSpec::parse(&spec.label()).unwrap(), spec);
        }
    }

    #[test]
    fn config_text_parsing() {
        let mut cfg = TrainConfig::default();
        cfg.apply_text(
            "[model]\npreset = micro  # comment\n\n[opt]\noptimizer = gwt-3\nlr = 0.02\nnl_gamma=1.05\nmodulewise_lr = false\nthreads = 4\n",
        )
        .unwrap();
        assert_eq!(cfg.preset, "micro");
        assert_eq!(cfg.optimizer, OptSpec::Gwt { level: 3 });
        assert_eq!(cfg.lr, 0.02);
        assert_eq!(cfg.nl_gamma, 1.05);
        assert!(!cfg.modulewise_lr);
        assert_eq!(cfg.threads, 4);
    }

    #[test]
    fn threads_resolution() {
        let mut cfg = TrainConfig::default();
        // Explicit values are honored as-is.
        cfg.threads = 7;
        assert_eq!(cfg.resolve_threads(), 7);
        // Auto-detect is positive and capped by the preset's tensor
        // count (one worker per parameter is the useful maximum).
        cfg.threads = 0;
        let auto = cfg.resolve_threads();
        assert!(auto >= 1);
        let cap = presets::find(&cfg.preset).unwrap().max_step_workers();
        assert!(auto <= cap, "auto {auto} > cap {cap}");
    }

    #[test]
    fn config_rejects_bad_lines() {
        let mut cfg = TrainConfig::default();
        assert!(cfg.apply_text("nonsense line").is_err());
        assert!(cfg.apply_text("unknown_key = 3").is_err());
        assert!(cfg.apply_text("steps = many").is_err());
    }

    #[test]
    fn validate_catches_errors() {
        let mut cfg = TrainConfig::default();
        cfg.preset = "nope".into();
        assert!(cfg.validate().is_err());
        cfg.preset = "nano".into();
        cfg.validate().unwrap();
        cfg.steps = 0;
        assert!(cfg.validate().is_err());
        cfg.steps = 10;
        // nano width 160: 160 % 2^6 != 0 -> invalid level.
        cfg.optimizer = OptSpec::Gwt { level: 6 };
        assert!(cfg.validate().is_err());
        cfg.optimizer = OptSpec::Gwt { level: 5 };
        cfg.validate().unwrap();
    }
}
