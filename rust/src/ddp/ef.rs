//! Error-feedback accumulators for the compressed all-reduce.
//!
//! The approximation-band reduce is a *biased* compressor: detail
//! bands are dropped every combine, so their gradient energy never
//! reaches the optimizer. Textbook error feedback (EF/EF21) with this
//! projection would be a mathematical no-op — the band truncation is
//! a fixed orthogonal projector, so the residual (the detail bands)
//! is exactly the component the transmitted subspace can never carry;
//! adding it back before truncating changes nothing.
//!
//! What does recover the lost energy is **delayed delivery**: each
//! replica keeps the detail bands its previous combine dropped (in
//! coefficient domain), and the next combine tree-averages those
//! saved residuals into the output's detail positions — coefficients
//! the optimizer then actually steps on through its coefficient seam.
//! The compressed path thus sees full coefficient information with a
//! one-combine lag on the detail bands, instead of never:
//!
//! ```text
//! combine(t):  wire     = mean_r approx(fwd(g_r(t)))     (unchanged)
//!              details  = mean_r e_r                     (residuals of t-1)
//!              e_r     <- details(fwd(g_r(t)))           (overwrite)
//! ```
//!
//! Residuals start zero, which makes the first EF-on combine bitwise
//! the EF-off combine. Wire and ledger bytes are unchanged — the
//! residual exchange rides the shared address space of the in-process
//! replicas (see docs/ddp.md for the multi-process transport caveat).
//! Buffers are bounded (`R × rows × (cols - q)` f32 per planned
//! parameter — no accumulation growth, since capture overwrites), are
//! charged to the serve admission budget via
//! [`crate::memory::ef_state_bytes`], and ride the checkpoint seam
//! (`ddp::ef::{param}::{replica}` keys) so suspend→resume stays
//! bit-identical.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::memory::ParamShape;
use crate::tensor::Tensor;

/// Per-parameter, per-replica residual store: the detail bands
/// (coefficient domain) dropped by the previous approximation-band
/// combine. Owned by [`super::GradReducer`] when `ddp_error_feedback`
/// is on; slots are sized lazily from the band plan at the first
/// combine (or from checkpoint tensors on restore).
pub struct ErrorFeedback {
    replicas: usize,
    slots: Vec<Option<EfSlot>>,
}

struct EfSlot {
    rows: usize,
    detail_cols: usize,
    /// One residual buffer per replica, in ascending replica order —
    /// the same fixed order the reduce tree is defined over.
    per_replica: Vec<Vec<f32>>,
}

impl ErrorFeedback {
    pub fn new(replicas: usize) -> ErrorFeedback {
        assert!(replicas > 1, "error feedback needs replicas > 1");
        ErrorFeedback { replicas, slots: Vec::new() }
    }

    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Make sure slot `idx` holds `rows × detail_cols` buffers for
    /// every replica, zero-initialized. Zero residuals are what make
    /// the first EF-on combine bitwise the EF-off combine. A geometry
    /// change (never expected mid-job — plans are stable for
    /// non-adaptive specs) resets the slot to zeros.
    pub fn ensure(&mut self, idx: usize, rows: usize, detail_cols: usize) {
        if self.slots.len() <= idx {
            self.slots.resize_with(idx + 1, || None);
        }
        let fits = matches!(
            &self.slots[idx],
            Some(s) if s.rows == rows && s.detail_cols == detail_cols
        );
        if !fits {
            self.slots[idx] = Some(EfSlot {
                rows,
                detail_cols,
                per_replica: vec![
                    vec![0.0; rows * detail_cols];
                    self.replicas
                ],
            });
        }
    }

    /// The stored residuals for parameter `idx`, one buffer per
    /// replica in ascending order. Callers `ensure` first.
    pub fn residuals(&self, idx: usize) -> &[Vec<f32>] {
        &self.slots[idx]
            .as_ref()
            .expect("EF slot read before ensure")
            .per_replica
    }

    /// Overwrite replica `r`'s residual for parameter `idx` with the
    /// detail portion of the full coefficient tensor `coeffs`
    /// (`rows × cols` row-major, band layout `[A_l | D_l | … | D_1]`,
    /// `q` approximation columns) — exactly the bands this combine
    /// drops from the wire. Overwrite, not accumulate: the previous
    /// residual was fully delivered by this combine's detail mean.
    pub fn capture(
        &mut self,
        idx: usize,
        r: usize,
        coeffs: &[f32],
        cols: usize,
        q: usize,
    ) {
        let slot = self.slots[idx]
            .as_mut()
            .expect("EF slot written before ensure");
        debug_assert_eq!(slot.detail_cols, cols - q);
        let buf = &mut slot.per_replica[r];
        for (brow, crow) in
            buf.chunks_exact_mut(cols - q).zip(coeffs.chunks_exact(cols))
        {
            brow.copy_from_slice(&crow[q..]);
        }
    }

    /// Measured bytes currently held (f32 residuals) — what the serve
    /// accountant budgets via [`crate::memory::ef_state_bytes`].
    pub fn state_bytes(&self) -> usize {
        self.slots
            .iter()
            .flatten()
            .map(|s| s.per_replica.len() * s.rows * s.detail_cols * 4)
            .sum()
    }

    /// Global L2 norm over every stored residual (the obs gauge; f64
    /// accumulation so the gauge is stable for large banks).
    pub fn residual_norm(&self) -> f64 {
        let ss: f64 = self
            .slots
            .iter()
            .flatten()
            .flat_map(|s| s.per_replica.iter())
            .flat_map(|b| b.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum();
        ss.sqrt()
    }

    /// Export every buffer for the checkpoint seam: key
    /// `ddp::ef::{param-name}::{replica}`, tensor shape
    /// `[rows, detail_cols]`. Slot indices map through `shapes` (bank
    /// order), so the keys are stable across suspend/resume.
    pub fn export_state(&self, shapes: &[ParamShape]) -> Vec<(String, Tensor)> {
        let mut out = Vec::new();
        for (idx, slot) in self.slots.iter().enumerate() {
            let Some(slot) = slot else { continue };
            let name = &shapes[idx].name;
            for (r, buf) in slot.per_replica.iter().enumerate() {
                out.push((
                    format!("ddp::ef::{name}::{r}"),
                    Tensor::new(&[slot.rows, slot.detail_cols], buf.clone()),
                ));
            }
        }
        out
    }

    /// Restore buffers exported by [`ErrorFeedback::export_state`].
    /// Geometry comes from the checkpoint tensors themselves — the
    /// band plan is not resolved until the first post-restore step —
    /// and the post-import combine stream is bit-identical to the
    /// exporter's (pinned in `rust/tests/ddp_determinism.rs`).
    pub fn import_state(
        &mut self,
        state: &BTreeMap<String, Tensor>,
        shapes: &[ParamShape],
    ) -> Result<()> {
        for (key, t) in state {
            let Some(rest) = key.strip_prefix("ddp::ef::") else {
                continue;
            };
            let Some((name, rep)) = rest.rsplit_once("::") else {
                bail!("malformed EF checkpoint key '{key}'");
            };
            let Some(idx) = shapes.iter().position(|p| p.name == name) else {
                bail!("EF checkpoint key '{key}' names an unknown parameter");
            };
            let r: usize = rep
                .parse()
                .with_context(|| format!("EF checkpoint key '{key}'"))?;
            if r >= self.replicas {
                bail!(
                    "EF checkpoint key '{key}' replica {r} out of range \
                     (replicas = {})",
                    self.replicas
                );
            }
            let shape = t.shape();
            if shape.len() != 2 {
                bail!("EF checkpoint tensor '{key}' is not 2-D");
            }
            self.ensure(idx, shape[0], shape[1]);
            self.slots[idx]
                .as_mut()
                .unwrap()
                .per_replica[r]
                .copy_from_slice(t.data());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shapes() -> Vec<ParamShape> {
        vec![
            ParamShape {
                name: "blk.attn".into(),
                shape: vec![4, 16],
                eligible: true,
            },
            ParamShape { name: "norm".into(), shape: vec![8], eligible: false },
        ]
    }

    #[test]
    fn ensure_capture_and_norm() {
        let mut ef = ErrorFeedback::new(2);
        ef.ensure(0, 2, 3);
        assert_eq!(ef.residuals(0).len(), 2);
        assert!(ef.residuals(0).iter().all(|b| b.iter().all(|&x| x == 0.0)));
        assert_eq!(ef.state_bytes(), 2 * 2 * 3 * 4);
        assert_eq!(ef.residual_norm(), 0.0);
        // cols=4, q=1: capture keeps columns 1..4 of each row.
        let coeffs = vec![9.0, 1.0, 2.0, 2.0, 9.0, 0.0, 0.0, 4.0];
        ef.ensure(0, 2, 3);
        ef.capture(0, 1, &coeffs, 4, 1);
        assert_eq!(ef.residuals(0)[1], vec![1.0, 2.0, 2.0, 0.0, 0.0, 4.0]);
        assert_eq!(ef.residuals(0)[0], vec![0.0; 6]);
        // sqrt(1+4+4+16) = 5.
        assert_eq!(ef.residual_norm(), 5.0);
        // Capture overwrites — no accumulation growth.
        ef.capture(0, 1, &[0.0; 8], 4, 1);
        assert_eq!(ef.residual_norm(), 0.0);
    }

    #[test]
    fn export_import_roundtrip() {
        let mut ef = ErrorFeedback::new(2);
        ef.ensure(0, 4, 8);
        let coeffs: Vec<f32> = (0..4 * 16).map(|i| i as f32).collect();
        ef.capture(0, 0, &coeffs, 16, 8);
        ef.capture(0, 1, &coeffs, 16, 8);
        let state: BTreeMap<String, Tensor> =
            ef.export_state(&shapes()).into_iter().collect();
        assert_eq!(state.len(), 2);
        assert!(state.contains_key("ddp::ef::blk.attn::0"));
        assert_eq!(state["ddp::ef::blk.attn::1"].shape(), &[4, 8]);
        let mut restored = ErrorFeedback::new(2);
        restored.import_state(&state, &shapes()).unwrap();
        for r in 0..2 {
            assert_eq!(restored.residuals(0)[r], ef.residuals(0)[r]);
        }
        assert_eq!(restored.state_bytes(), ef.state_bytes());
    }

    #[test]
    fn import_rejects_malformed_keys() {
        let shapes = shapes();
        let mut ef = ErrorFeedback::new(2);
        let t = Tensor::new(&[1, 2], vec![0.0, 0.0]);
        // Unknown parameter name.
        let mut state = BTreeMap::new();
        state.insert("ddp::ef::ghost::0".to_string(), t.clone());
        assert!(ef.import_state(&state, &shapes).is_err());
        // Replica out of range.
        let mut state = BTreeMap::new();
        state.insert("ddp::ef::blk.attn::7".to_string(), t.clone());
        assert!(ef.import_state(&state, &shapes).is_err());
        // Non-numeric replica segment.
        let mut state = BTreeMap::new();
        state.insert("ddp::ef::blk.attn::x".to_string(), t);
        assert!(ef.import_state(&state, &shapes).is_err());
        // Foreign keys (params, opt state) are simply skipped.
        let mut state = BTreeMap::new();
        state.insert("opt::blk.attn::m".to_string(), Tensor::zeros(&[2]));
        ef.import_state(&state, &shapes).unwrap();
        assert_eq!(ef.state_bytes(), 0);
    }
}
