//! SIMD DB4 level kernels (AVX2 / NEON), bit-identical to
//! [`super::db4_fwd_level_scalar`] / [`super::db4_inv_level_scalar`].
//!
//! Forward: output `(a_i, d_i)` is a 4-tap stencil over
//! `x[2i..2i+4]` (mod n). The scalar loop accumulates tap by tap
//! from a literal `0.0`; the vector form does the identical
//! `acc = ((((0 + H0·x0) + H1·x1) + H2·x2) + H3·x3)` chain with
//! splatted coefficients — separate mul and add intrinsics, never an
//! FMA, and an explicit leading zero-add (observable: `0.0 + (-0.0)`
//! is `+0.0`, and -0.0 products arise from underflow). Lanes cover
//! only stencils that don't wrap (`i <= half-2`); the wrap stencil
//! and sub-lane tails run the shared scalar helpers.
//!
//! Inverse: each output pair `(out[2p], out[2p+1])` receives exactly
//! two stencil contributions, accumulated in the historical scatter
//! order (see `db4_inv_point` / `db4_inv_point0` in the parent
//! module). The vector form reproduces that same
//! `(0 + (H·a_prev + G·d_prev)) + (H·a_cur + G·d_cur)` grouping per
//! lane for `p >= 1`; the wrapping pair `p = 0` is always scalar.

#[cfg(target_arch = "x86_64")]
pub mod avx2 {
    use crate::wavelet::db4::{G, H};
    use crate::wavelet::kernels::{db4_fwd_point, db4_inv_point, db4_inv_point0};
    use core::arch::x86_64::*;

    /// Safe entry: the dispatch table only selects this module after
    /// `is_x86_feature_detected!("avx2")`.
    pub fn db4_fwd_level(row: &mut [f32], scratch: &mut [f32]) {
        unsafe { db4_fwd_level_impl(row, scratch) }
    }

    pub fn db4_inv_level(row: &mut [f32], scratch: &mut [f32]) {
        unsafe { db4_inv_level_impl(row, scratch) }
    }

    /// Deinterleave 16 consecutive floats at `p` into 8 evens + 8 odds.
    #[target_feature(enable = "avx2")]
    unsafe fn evens_odds(p: *const f32) -> (__m256, __m256) {
        let idx = _mm256_setr_epi32(0, 2, 4, 6, 1, 3, 5, 7);
        let v0 = _mm256_permutevar8x32_ps(_mm256_loadu_ps(p), idx);
        let v1 = _mm256_permutevar8x32_ps(_mm256_loadu_ps(p.add(8)), idx);
        (
            _mm256_permute2f128_ps::<0x20>(v0, v1),
            _mm256_permute2f128_ps::<0x31>(v0, v1),
        )
    }

    #[target_feature(enable = "avx2")]
    unsafe fn db4_fwd_level_impl(row: &mut [f32], scratch: &mut [f32]) {
        let n = row.len();
        debug_assert!(n >= 2 && n % 2 == 0);
        debug_assert!(scratch.len() >= n);
        let half = n / 2;
        // Lanes only over stencils that stay in-bounds (2i+3 <= n-1).
        let interior = half - 1;
        let simd = interior - interior % 8;
        let zero = _mm256_setzero_ps();
        let h: [__m256; 4] = [
            _mm256_set1_ps(H[0]),
            _mm256_set1_ps(H[1]),
            _mm256_set1_ps(H[2]),
            _mm256_set1_ps(H[3]),
        ];
        let g: [__m256; 4] = [
            _mm256_set1_ps(G[0]),
            _mm256_set1_ps(G[1]),
            _mm256_set1_ps(G[2]),
            _mm256_set1_ps(G[3]),
        ];
        let rp = row.as_ptr();
        let sp = scratch.as_mut_ptr();
        let mut i = 0usize;
        while i < simd {
            // Taps 0/1 at offset 2i, taps 2/3 at offset 2i+2.
            let (x0, x1) = evens_odds(rp.add(2 * i));
            let (x2, x3) = evens_odds(rp.add(2 * i + 2));
            let mut a = _mm256_add_ps(zero, _mm256_mul_ps(h[0], x0));
            a = _mm256_add_ps(a, _mm256_mul_ps(h[1], x1));
            a = _mm256_add_ps(a, _mm256_mul_ps(h[2], x2));
            a = _mm256_add_ps(a, _mm256_mul_ps(h[3], x3));
            let mut d = _mm256_add_ps(zero, _mm256_mul_ps(g[0], x0));
            d = _mm256_add_ps(d, _mm256_mul_ps(g[1], x1));
            d = _mm256_add_ps(d, _mm256_mul_ps(g[2], x2));
            d = _mm256_add_ps(d, _mm256_mul_ps(g[3], x3));
            _mm256_storeu_ps(sp.add(i), a);
            _mm256_storeu_ps(sp.add(half + i), d);
            i += 8;
        }
        for i in simd..half {
            let (a, d) = db4_fwd_point(row, n, i);
            scratch[i] = a;
            scratch[half + i] = d;
        }
        row.copy_from_slice(&scratch[..n]);
    }

    #[target_feature(enable = "avx2")]
    unsafe fn db4_inv_level_impl(row: &mut [f32], scratch: &mut [f32]) {
        let n = row.len();
        debug_assert!(n >= 2 && n % 2 == 0);
        debug_assert!(scratch.len() >= n);
        let half = n / 2;
        let interior = half - 1; // pairs p = 1..half (p = 0 wraps)
        let simd = interior - interior % 8;
        let zero = _mm256_setzero_ps();
        let (h0, h1, h2, h3) = (
            _mm256_set1_ps(H[0]),
            _mm256_set1_ps(H[1]),
            _mm256_set1_ps(H[2]),
            _mm256_set1_ps(H[3]),
        );
        let (g0, g1, g2, g3) = (
            _mm256_set1_ps(G[0]),
            _mm256_set1_ps(G[1]),
            _mm256_set1_ps(G[2]),
            _mm256_set1_ps(G[3]),
        );
        let rp = row.as_ptr();
        let sp = scratch.as_mut_ptr();
        let mut p = 1usize;
        while p < 1 + simd {
            let ap = _mm256_loadu_ps(rp.add(p - 1));
            let dp = _mm256_loadu_ps(rp.add(half + p - 1));
            let ac = _mm256_loadu_ps(rp.add(p));
            let dc = _mm256_loadu_ps(rp.add(half + p));
            // (0 + (H2·ap + G2·dp)) + (H0·ac + G0·dc), per lane.
            let t1e = _mm256_add_ps(_mm256_mul_ps(h2, ap), _mm256_mul_ps(g2, dp));
            let t2e = _mm256_add_ps(_mm256_mul_ps(h0, ac), _mm256_mul_ps(g0, dc));
            let ev = _mm256_add_ps(_mm256_add_ps(zero, t1e), t2e);
            let t1o = _mm256_add_ps(_mm256_mul_ps(h3, ap), _mm256_mul_ps(g3, dp));
            let t2o = _mm256_add_ps(_mm256_mul_ps(h1, ac), _mm256_mul_ps(g1, dc));
            let od = _mm256_add_ps(_mm256_add_ps(zero, t1o), t2o);
            let lo = _mm256_unpacklo_ps(ev, od);
            let hi = _mm256_unpackhi_ps(ev, od);
            _mm256_storeu_ps(sp.add(2 * p), _mm256_permute2f128_ps::<0x20>(lo, hi));
            _mm256_storeu_ps(
                sp.add(2 * p + 8),
                _mm256_permute2f128_ps::<0x31>(lo, hi),
            );
            p += 8;
        }
        for p in (1 + simd)..half {
            let (e, o) = db4_inv_point(row, half, p);
            scratch[2 * p] = e;
            scratch[2 * p + 1] = o;
        }
        let (e0, o0) = db4_inv_point0(row, half);
        scratch[0] = e0;
        scratch[1] = o0;
        row.copy_from_slice(&scratch[..n]);
    }
}

#[cfg(target_arch = "aarch64")]
pub mod neon {
    use crate::wavelet::db4::{G, H};
    use crate::wavelet::kernels::{db4_fwd_point, db4_inv_point, db4_inv_point0};
    use core::arch::aarch64::*;

    /// Safe entry: NEON is baseline on aarch64.
    pub fn db4_fwd_level(row: &mut [f32], scratch: &mut [f32]) {
        unsafe { db4_fwd_level_impl(row, scratch) }
    }

    pub fn db4_inv_level(row: &mut [f32], scratch: &mut [f32]) {
        unsafe { db4_inv_level_impl(row, scratch) }
    }

    unsafe fn db4_fwd_level_impl(row: &mut [f32], scratch: &mut [f32]) {
        let n = row.len();
        debug_assert!(n >= 2 && n % 2 == 0);
        debug_assert!(scratch.len() >= n);
        let half = n / 2;
        let interior = half - 1;
        let simd = interior - interior % 4;
        let zero = vdupq_n_f32(0.0);
        let h: [float32x4_t; 4] = [
            vdupq_n_f32(H[0]),
            vdupq_n_f32(H[1]),
            vdupq_n_f32(H[2]),
            vdupq_n_f32(H[3]),
        ];
        let g: [float32x4_t; 4] = [
            vdupq_n_f32(G[0]),
            vdupq_n_f32(G[1]),
            vdupq_n_f32(G[2]),
            vdupq_n_f32(G[3]),
        ];
        let rp = row.as_ptr();
        let sp = scratch.as_mut_ptr();
        let mut i = 0usize;
        while i < simd {
            let t01 = vld2q_f32(rp.add(2 * i)); // .0 = taps 0, .1 = taps 1
            let t23 = vld2q_f32(rp.add(2 * i + 2)); // .0 = taps 2, .1 = taps 3
            let mut a = vaddq_f32(zero, vmulq_f32(h[0], t01.0));
            a = vaddq_f32(a, vmulq_f32(h[1], t01.1));
            a = vaddq_f32(a, vmulq_f32(h[2], t23.0));
            a = vaddq_f32(a, vmulq_f32(h[3], t23.1));
            let mut d = vaddq_f32(zero, vmulq_f32(g[0], t01.0));
            d = vaddq_f32(d, vmulq_f32(g[1], t01.1));
            d = vaddq_f32(d, vmulq_f32(g[2], t23.0));
            d = vaddq_f32(d, vmulq_f32(g[3], t23.1));
            vst1q_f32(sp.add(i), a);
            vst1q_f32(sp.add(half + i), d);
            i += 4;
        }
        for i in simd..half {
            let (a, d) = db4_fwd_point(row, n, i);
            scratch[i] = a;
            scratch[half + i] = d;
        }
        row.copy_from_slice(&scratch[..n]);
    }

    unsafe fn db4_inv_level_impl(row: &mut [f32], scratch: &mut [f32]) {
        let n = row.len();
        debug_assert!(n >= 2 && n % 2 == 0);
        debug_assert!(scratch.len() >= n);
        let half = n / 2;
        let interior = half - 1;
        let simd = interior - interior % 4;
        let zero = vdupq_n_f32(0.0);
        let (h0, h1, h2, h3) = (
            vdupq_n_f32(H[0]),
            vdupq_n_f32(H[1]),
            vdupq_n_f32(H[2]),
            vdupq_n_f32(H[3]),
        );
        let (g0, g1, g2, g3) = (
            vdupq_n_f32(G[0]),
            vdupq_n_f32(G[1]),
            vdupq_n_f32(G[2]),
            vdupq_n_f32(G[3]),
        );
        let rp = row.as_ptr();
        let sp = scratch.as_mut_ptr();
        let mut p = 1usize;
        while p < 1 + simd {
            let ap = vld1q_f32(rp.add(p - 1));
            let dp = vld1q_f32(rp.add(half + p - 1));
            let ac = vld1q_f32(rp.add(p));
            let dc = vld1q_f32(rp.add(half + p));
            let t1e = vaddq_f32(vmulq_f32(h2, ap), vmulq_f32(g2, dp));
            let t2e = vaddq_f32(vmulq_f32(h0, ac), vmulq_f32(g0, dc));
            let ev = vaddq_f32(vaddq_f32(zero, t1e), t2e);
            let t1o = vaddq_f32(vmulq_f32(h3, ap), vmulq_f32(g3, dp));
            let t2o = vaddq_f32(vmulq_f32(h1, ac), vmulq_f32(g1, dc));
            let od = vaddq_f32(vaddq_f32(zero, t1o), t2o);
            vst2q_f32(sp.add(2 * p), float32x4x2_t(ev, od));
            p += 4;
        }
        for p in (1 + simd)..half {
            let (e, o) = db4_inv_point(row, half, p);
            scratch[2 * p] = e;
            scratch[2 * p + 1] = o;
        }
        let (e0, o0) = db4_inv_point0(row, half);
        scratch[0] = e0;
        scratch[1] = o0;
        row.copy_from_slice(&scratch[..n]);
    }
}
