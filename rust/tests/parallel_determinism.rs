//! Step-engine determinism: the parallel optimizer step must be
//! *bit-identical* to the serial one — same weights, same stats —
//! for every optimizer spec and every worker count. This is the
//! contract that makes `TrainConfig::threads` a pure throughput knob
//! (fixed chunk boundaries, no cross-item reductions, each item
//! processed by the same single-threaded code as the serial loop).
//!
//! Runs entirely on the pure-rust optimizer paths (no artifacts
//! needed), so it exercises the full bank: GWT row sharding included.

use gwt::adapt::{selections, AdaptController, AdaptPolicy};
use gwt::config::{InnerSpec, OptSpec, TrainConfig, TransformSpec};
use gwt::memory::ParamShape;
use gwt::optim::{build_optimizers, step_bank};
use gwt::pool::{chunk_bounds, scoped_chunks_mut};
use gwt::rng::Rng;
use gwt::tensor::Tensor;
use gwt::wavelet::WaveletBasis;

fn nano_shapes() -> Vec<ParamShape> {
    gwt::config::presets::find("nano").unwrap().param_shapes()
}

const ALL_SPECS: &[OptSpec] = &[
    OptSpec::adam(),
    OptSpec::gwt(2),
    OptSpec::gwt(3),
    OptSpec::gwt_basis(WaveletBasis::Db4, 2),
    OptSpec::gwt_basis(WaveletBasis::Db4, 3),
    OptSpec::galore(4),
    OptSpec::apollo(4),
    OptSpec::lora(4),
    OptSpec::adam_mini(),
    OptSpec::Muon,
    OptSpec::adam8bit(),
    OptSpec::sgdm(),
    // Composed specs: every generic transform x inner pairing class
    // must honor the same bank-level bit-identity contract.
    OptSpec::composed(
        TransformSpec::wavelet(WaveletBasis::Haar, 2),
        InnerSpec::Adam8bit,
    ),
    OptSpec::composed(
        TransformSpec::wavelet(WaveletBasis::Db4, 2),
        InnerSpec::SgdM,
    ),
    OptSpec::composed(
        TransformSpec::wavelet(WaveletBasis::Haar, 3),
        InnerSpec::AdamMini,
    ),
    OptSpec::composed(TransformSpec::LowRank { rank_denom: 4 }, InnerSpec::SgdM),
    OptSpec::composed(
        TransformSpec::RandomProj { rank_denom: 4 },
        InnerSpec::Adam8bit,
    ),
    // Adaptive engines ride the same bank contract; without the
    // controller in the loop they run at their init selection (the
    // adaptive pipeline with live migrations is pinned separately
    // below).
    OptSpec::adaptive(AdaptPolicy::Greedy),
    OptSpec::composed(
        TransformSpec::Adaptive { policy: AdaptPolicy::Anneal },
        InnerSpec::SgdM,
    ),
];

fn init_weights(shapes: &[ParamShape], seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    shapes
        .iter()
        .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
        .collect()
}

fn step_grads(shapes: &[ParamShape], step: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(50 + step);
    shapes
        .iter()
        .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
        .collect()
}

#[test]
fn parallel_bank_bit_identical_for_every_optimizer() {
    let shapes = nano_shapes();
    for &opt in ALL_SPECS {
        let cfg = TrainConfig { optimizer: opt, ..Default::default() };
        // Serial reference run.
        let mut ser_bank = build_optimizers(&shapes, &cfg, None).unwrap();
        let mut ser_w = init_weights(&shapes, 1);
        let mut ser_stats = Vec::new();
        for step in 0..3u64 {
            let grads = step_grads(&shapes, step);
            ser_stats.push(step_bank(&mut ser_bank, &mut ser_w, &grads, 0.01, 1));
        }
        for threads in [2usize, 4, 7] {
            let mut bank = build_optimizers(&shapes, &cfg, None).unwrap();
            let mut w = init_weights(&shapes, 1);
            for (step, ser) in ser_stats.iter().enumerate() {
                let grads = step_grads(&shapes, step as u64);
                let stats = step_bank(&mut bank, &mut w, &grads, 0.01, threads);
                // Stats come back in bank order with the exact serial
                // bits, regardless of which worker produced them.
                assert_eq!(stats.len(), ser.len());
                for (i, (a, b)) in stats.iter().zip(ser).enumerate() {
                    assert_eq!(
                        a.update_norm.to_bits(),
                        b.update_norm.to_bits(),
                        "{opt:?} threads={threads} step={step} param {i} norm"
                    );
                    assert_eq!(
                        a.limiter_scale.to_bits(),
                        b.limiter_scale.to_bits(),
                        "{opt:?} threads={threads} step={step} param {i} scale"
                    );
                }
            }
            for (i, (a, b)) in ser_w.iter().zip(&w).enumerate() {
                assert_eq!(
                    a.data(),
                    b.data(),
                    "{opt:?} threads={threads} param {} ({})",
                    i,
                    shapes[i].name
                );
            }
        }
    }
}

/// Block-constant gradients (width 16) drive the greedy/anneal
/// policies to deepen from the init level 2 — a migration is
/// guaranteed to fire within the run.
fn compressible_grads(shapes: &[ParamShape], step: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(7000 + step);
    shapes
        .iter()
        .map(|s| {
            if s.shape.len() == 2 {
                let (m, n) = (s.shape[0], s.shape[1]);
                let mut gd = vec![0.0f32; m * n];
                for r in 0..m {
                    for blk in 0..n / 16 {
                        let v = rng.normal_f32();
                        for j in 0..16 {
                            gd[r * n + blk * 16 + j] = v;
                        }
                    }
                }
                Tensor::new(&s.shape, gd)
            } else {
                Tensor::randn(&s.shape, 1.0, &mut rng)
            }
        })
        .collect()
}

#[test]
fn adaptive_pipeline_bit_identical_with_migrations() {
    // The full adaptive pipeline — parallel step, sharded probe,
    // serial policy, migration — must be bit-identical across worker
    // counts, including the steps where migrations fire.
    let shapes = nano_shapes();
    for policy in [AdaptPolicy::Greedy, AdaptPolicy::Anneal] {
        let mut cfg = TrainConfig {
            optimizer: OptSpec::adaptive(policy),
            ..Default::default()
        };
        cfg.adapt_cadence = 2;
        let run = |threads: usize| {
            let mut bank = build_optimizers(&shapes, &cfg, None).unwrap();
            let mut ctl = AdaptController::from_config(&cfg).unwrap();
            let mut w = init_weights(&shapes, 3);
            let mut migrations = 0usize;
            for step in 1..=6u64 {
                let grads = compressible_grads(&shapes, step);
                step_bank(&mut bank, &mut w, &grads, 0.01, threads);
                if let Some(ev) =
                    ctl.post_step(step as usize, &mut bank, &grads, threads)
                {
                    migrations += ev.migrations;
                }
            }
            (w, selections(&mut bank), migrations)
        };
        let (ser_w, ser_sel, ser_migs) = run(1);
        assert!(
            ser_migs > 0,
            "{policy:?}: compressible gradients must trigger a migration"
        );
        // The selections actually moved off the init (Haar, 2).
        assert!(
            ser_sel.iter().any(|s| *s != (WaveletBasis::Haar, 2)),
            "{policy:?}: {ser_sel:?}"
        );
        for threads in [2usize, 4, 7] {
            let (w, sel, migs) = run(threads);
            assert_eq!(sel, ser_sel, "{policy:?} threads={threads} selections");
            assert_eq!(migs, ser_migs, "{policy:?} threads={threads} events");
            for (i, (a, b)) in ser_w.iter().zip(&w).enumerate() {
                assert_eq!(
                    a.data(),
                    b.data(),
                    "{policy:?} threads={threads} param {} ({})",
                    i,
                    shapes[i].name
                );
            }
        }
    }
}

#[test]
fn single_param_row_sharding_matches_serial() {
    // With a one-param bank, build_optimizers routes the thread
    // budget into GwtAdam's row sharding instead of the bank level;
    // the result must still match the serial run bit-for-bit — for
    // every wavelet basis (the row kernel is basis-dispatched but
    // identical across workers).
    for basis in WaveletBasis::ALL {
        let shape = ParamShape {
            name: "layers.00.attn.wq".into(),
            shape: vec![32, 64],
            eligible: true,
        };
        let mk = |threads: usize| {
            let cfg = TrainConfig {
                optimizer: OptSpec::gwt_basis(basis, 3),
                threads,
                ..Default::default()
            };
            build_optimizers(std::slice::from_ref(&shape), &cfg, None).unwrap()
        };
        let mut serial = mk(1);
        let mut sharded = mk(4);
        let mut rng = Rng::new(9);
        let mut w1 = vec![Tensor::randn(&[32, 64], 1.0, &mut rng)];
        let mut w2 = w1.clone();
        for step in 0..3u64 {
            let mut grng = Rng::new(70 + step);
            let g = vec![Tensor::randn(&[32, 64], 1.0, &mut grng)];
            step_bank(&mut serial, &mut w1, &g, 0.01, 1);
            step_bank(&mut sharded, &mut w2, &g, 0.01, 1);
        }
        assert_eq!(w1[0].data(), w2[0].data(), "{basis:?}");
    }
}

#[test]
fn zero_workers_and_one_param_edge_cases() {
    // chunk_bounds: zero workers behaves as one; empty input is empty.
    assert_eq!(chunk_bounds(5, 0), vec![(0, 5)]);
    assert!(chunk_bounds(0, 4).is_empty());
    // scoped_chunks_mut with zero workers runs inline on the caller.
    let mut xs = vec![1u32, 2, 3];
    scoped_chunks_mut(&mut xs, 0, |_| (), |_, _, c| {
        for x in c.iter_mut() {
            *x += 1;
        }
    });
    assert_eq!(xs, vec![2, 3, 4]);
    // A one-param bank sharded over many workers steps exactly once.
    let shape = ParamShape {
        name: "layers.00.attn.wq".into(),
        shape: vec![16, 16],
        eligible: true,
    };
    let cfg = TrainConfig {
        optimizer: OptSpec::gwt(2),
        ..Default::default()
    };
    let mut bank =
        build_optimizers(std::slice::from_ref(&shape), &cfg, None).unwrap();
    let mut rng = Rng::new(3);
    let mut w = vec![Tensor::randn(&[16, 16], 1.0, &mut rng)];
    let g = vec![Tensor::randn(&[16, 16], 1.0, &mut rng)];
    let before = w[0].clone();
    let stats = step_bank(&mut bank, &mut w, &g, 0.01, 7);
    assert_eq!(stats.len(), 1);
    assert!(stats[0].update_norm > 0.0);
    assert_ne!(before.data(), w[0].data());
    // Empty bank: no-op, no panic.
    let stats = step_bank(&mut [], &mut [], &[], 0.01, 4);
    assert!(stats.is_empty());
}

#[test]
fn step_bank_zero_threads_is_serial() {
    let shapes = nano_shapes();
    let cfg = TrainConfig {
        optimizer: OptSpec::gwt(2),
        ..Default::default()
    };
    let mut a_bank = build_optimizers(&shapes, &cfg, None).unwrap();
    let mut b_bank = build_optimizers(&shapes, &cfg, None).unwrap();
    let mut a_w = init_weights(&shapes, 5);
    let mut b_w = a_w.clone();
    let grads = step_grads(&shapes, 0);
    step_bank(&mut a_bank, &mut a_w, &grads, 0.01, 0);
    step_bank(&mut b_bank, &mut b_w, &grads, 0.01, 1);
    for (a, b) in a_w.iter().zip(&b_w) {
        assert_eq!(a.data(), b.data());
    }
}
