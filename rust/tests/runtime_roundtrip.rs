//! Integration: AOT HLO artifacts executed through the rust PJRT
//! runtime, numerics pinned against the in-repo rust oracle (which is
//! itself pinned against the Python reference by the pytest suite —
//! closing the loop across all three layers).
//!
//! Requires `make artifacts`. Tests skip (with a notice) if the
//! manifest is missing so plain `cargo test` works pre-build.

use std::sync::Arc;

use gwt::optim::{AdamHp, GwtAdam, MatrixOpt};
use gwt::rng::Rng;
use gwt::runtime::{literal_f32, tensor_from_literal, Runtime};
use gwt::tensor::Tensor;

fn runtime() -> Option<Arc<Runtime>> {
    match Runtime::load("artifacts") {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

#[test]
fn haar_fwd_artifact_matches_rust_oracle() {
    let Some(rt) = runtime() else { return };
    let exec = rt.exec("haar_fwd_l2_16x32").unwrap();
    let mut rng = Rng::new(1);
    let x = Tensor::randn(&[16, 32], 1.0, &mut rng);
    let outs = exec.run(&[literal_f32(&x).unwrap()]).unwrap();
    let got = tensor_from_literal(&outs[0], &[16, 32]).unwrap();
    let want = gwt::wavelet::haar_fwd(x.data(), 16, 32, 2);
    gwt::testing::approx_eq_slice(got.data(), &want, 1e-5);
}

#[test]
fn haar_inv_artifact_roundtrips_fwd() {
    let Some(rt) = runtime() else { return };
    let fwd = rt.exec("haar_fwd_l3_8x64").unwrap();
    let inv = rt.exec("haar_inv_l3_8x64").unwrap();
    let mut rng = Rng::new(2);
    let x = Tensor::randn(&[8, 64], 1.0, &mut rng);
    let c = fwd.run(&[literal_f32(&x).unwrap()]).unwrap();
    let back = inv.run(&[c[0].clone()]).unwrap();
    let got = tensor_from_literal(&back[0], &[8, 64]).unwrap();
    gwt::testing::approx_eq_slice(got.data(), x.data(), 1e-4);
}

#[test]
fn gwt_adam_hlo_path_matches_rust_path() {
    let Some(rt) = runtime() else { return };
    // Same shape/level, one with the HLO artifact, one pure rust.
    let hp = AdamHp::default();
    let mut hlo = GwtAdam::new(64, 64, 2, hp, Some(rt.clone())).unwrap();
    let mut rust = GwtAdam::new(64, 64, 2, hp, None).unwrap();
    assert!(hlo.uses_hlo(), "expected gwt_adam_l2_64x64 artifact");
    assert!(!rust.uses_hlo());
    let mut rng = Rng::new(3);
    for step in 0..5 {
        let g = Tensor::randn(&[64, 64], 1.0, &mut rng);
        let a = hlo.direction(&g, 0.0);
        let b = rust.direction(&g, 0.0);
        // Detail/approx division can amplify tiny denominator
        // differences; compare with mixed tolerance.
        for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
            let diff = (x - y).abs();
            assert!(
                diff <= 1e-3 + 1e-3 * y.abs(),
                "step {step} idx {i}: hlo {x} vs rust {y}"
            );
        }
    }
}

#[test]
fn failed_hlo_step_preserves_moments_and_falls_back() {
    // Satellite regression: the HLO path used to `mem::take` the
    // moments before running the executable and `.expect` on the
    // result — any runtime failure aborted training with destroyed
    // optimizer state. Now a failed step must (a) leave m/v intact
    // and (b) fall back to the rust path, so the first "failed" step
    // is bit-identical to a pure-rust twin with the same history.
    let Some(rt) = runtime() else { return };
    let hp = AdamHp::default();
    let mut bad = GwtAdam::new(64, 64, 2, hp, None).unwrap();
    let mut rust = GwtAdam::new(64, 64, 2, hp, None).unwrap();
    bad.force_hlo_key(rt.clone(), "no_such_artifact".into());
    assert!(bad.uses_hlo());
    let mut rng = Rng::new(7);
    for step in 0..3 {
        let g = Tensor::randn(&[64, 64], 1.0, &mut rng);
        let a = bad.direction(&g, 0.0);
        let b = rust.direction(&g, 0.0);
        assert_eq!(
            a.data(),
            b.data(),
            "step {step}: fallback must match the rust path bit-for-bit"
        );
    }
    assert!(!bad.uses_hlo(), "failed HLO path must disable itself");
}

#[test]
fn adam_artifact_matches_rust_adam() {
    let Some(rt) = runtime() else { return };
    let exec = rt.exec("adam_64x64").unwrap();
    let mut rng = Rng::new(4);
    let g = Tensor::randn(&[64, 64], 1.0, &mut rng);
    let m = Tensor::randn(&[64, 64], 0.1, &mut rng);
    let mut vdata = rng.normal_vec(64 * 64, 0.05);
    for v in &mut vdata {
        *v = v.abs();
    }
    let v = Tensor::new(&[64, 64], vdata);
    let outs = exec
        .run(&[
            literal_f32(&g).unwrap(),
            literal_f32(&m).unwrap(),
            literal_f32(&v).unwrap(),
        ])
        .unwrap();
    let upd = tensor_from_literal(&outs[0], &[64, 64]).unwrap();
    // Rust-side expected (pre-bias-correction path in the artifact).
    let hp = AdamHp::default();
    let mut want = vec![0.0f32; 64 * 64];
    for i in 0..want.len() {
        let mn = hp.beta1 * m.data()[i] + (1.0 - hp.beta1) * g.data()[i];
        let vn =
            hp.beta2 * v.data()[i] + (1.0 - hp.beta2) * g.data()[i] * g.data()[i];
        want[i] = mn / (vn.sqrt() + hp.eps);
    }
    gwt::testing::approx_eq_slice(upd.data(), &want, 1e-4);
}

#[test]
fn train_step_artifact_runs_and_loss_is_sane() {
    let Some(rt) = runtime() else { return };
    let preset = gwt::config::presets::find("nano").unwrap();
    rt.manifest.check_preset(preset).unwrap();
    let exec = rt.exec("train_step_nano").unwrap();
    let mut rng = Rng::new(5);
    let shapes = preset.param_shapes();
    let mut inputs = Vec::new();
    for s in &shapes {
        inputs.push(
            literal_f32(&gwt::coordinator::trainer::init_param(
                &s.name, &s.shape, &mut rng,
            ))
            .unwrap(),
        );
    }
    let tokens: Vec<i32> = (0..preset.batch * preset.seq_len)
        .map(|_| 2 + rng.usize_below(254) as i32)
        .collect();
    inputs.push(
        gwt::runtime::literal_tokens(&tokens, preset.batch, preset.seq_len)
            .unwrap(),
    );
    let outs = exec.run(&inputs).unwrap();
    assert_eq!(outs.len(), 1 + shapes.len());
    let loss = gwt::runtime::scalar_from_literal(&outs[0]).unwrap();
    // Random init on 256-way vocab: loss near ln(256) = 5.545.
    assert!(
        (loss - 5.545).abs() < 1.5,
        "init loss {loss} far from ln(vocab)"
    );
    // Gradients: finite, correct shapes, not all zero.
    let mut total_norm = 0.0f64;
    for (i, s) in shapes.iter().enumerate() {
        let g = outs[1 + i].to_vec::<f32>().unwrap();
        assert_eq!(g.len(), s.numel(), "{}", s.name);
        assert!(g.iter().all(|x| x.is_finite()), "{}", s.name);
        total_norm += g.iter().map(|x| (*x as f64).powi(2)).sum::<f64>();
    }
    assert!(total_norm.sqrt() > 1e-3, "gradients all ~zero");
}

#[test]
fn manifest_validates_all_rust_presets() {
    let Some(rt) = runtime() else { return };
    for p in gwt::config::presets::PRESETS {
        rt.manifest
            .check_preset(p)
            .unwrap_or_else(|e| panic!("preset {}: {e:#}", p.name));
    }
}
