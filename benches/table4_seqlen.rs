//! Paper Table IV: robustness to longer sequences at constant
//! tokens/batch. Paper shape: GaLore degrades with sequence length
//! while GWT stays stable and best.

use gwt::bench_harness::{
    bench_loader, pretrain, runtime_or_skip, scaled, write_result, RunSpec,
    TableView,
};
use gwt::config::OptSpec;

/// Paper 60M reference PPLs for seq 512 / 1024.
const PAPER: &[(&str, f64, f64)] = &[
    ("Adam", 34.55, 37.52),
    ("GaLore-1/4", 40.25, 42.02),
    ("APOLLO-1/4", 32.29, 34.64),
    ("GWT-2", 30.12, 32.55),
];

fn main() -> anyhow::Result<()> {
    let rt = runtime_or_skip();
    let steps = scaled(160);
    // seq 64 -> 128 -> 256 with batch 8 -> 4 -> 2 (constant tokens).
    let presets = ["nano", "nano-s128", "nano-s256"];

    let mut table = TableView::new(
        "Table IV — sequence-length robustness (constant tokens/batch)",
        &[
            "method", "seq64 PPL", "seq128 PPL", "seq256 PPL",
            "paper s512", "paper s1024",
        ],
    );
    let mut measured = Vec::new();
    for (name, p512, p1024) in PAPER {
        let opt = OptSpec::parse(name).unwrap();
        let mut cells = vec![name.to_string()];
        let mut ppls = Vec::new();
        for preset in presets {
            let loader = bench_loader(preset, steps, 6);
            let spec = RunSpec::paper_defaults(preset, opt, steps);
            let out = pretrain(rt.clone(), &spec, &loader);
            println!("  {preset:<10} {name:<12} ppl {:.2}", out.valid_ppl);
            cells.push(format!("{:.2}", out.valid_ppl));
            ppls.push(out.valid_ppl);
        }
        cells.push(format!("{p512:.2}"));
        cells.push(format!("{p1024:.2}"));
        table.row(cells);
        measured.push((name.to_string(), ppls));
    }
    table.print();

    let get = |n: &str| &measured.iter().find(|(m, _)| m == n).unwrap().1;
    let gwt = get("GWT-2");
    let galore = get("GaLore-1/4");
    // Shape: GWT best at every length; GaLore's degradation with
    // length is at least as bad as GWT's.
    let gwt_best = (0..3).all(|i| gwt[i] <= galore[i]);
    let deg_gwt = gwt[2] - gwt[0];
    let deg_galore = galore[2] - galore[0];
    println!(
        "shape: GWT <= GaLore at all lengths [{}]; GaLore degradation {:.2} vs GWT {:.2} [{}]",
        if gwt_best { "OK" } else { "MISS" },
        deg_galore,
        deg_gwt,
        if deg_galore >= deg_gwt - 0.5 { "OK" } else { "MISS" }
    );
    write_result("table4_seqlen", &table, vec![])?;
    Ok(())
}
