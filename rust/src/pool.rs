//! Parallelism substrate: scoped worker mapping + allreduce.
//!
//! Stands in for the paper's multi-GPU DDP setup: each data-parallel
//! worker is a thread with its own data shard; gradients are combined
//! with a tree allreduce (same reduction topology NCCL would use, so
//! the coordinator logic is shaped correctly even though transport is
//! shared memory).

/// Run `f(worker_index)` on `n` threads and collect results in order.
pub fn scoped_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 1 {
        return vec![f(0)];
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|w| {
                let f = &f;
                scope.spawn(move || f(w))
            })
            .collect();
        for (slot, h) in out.iter_mut().zip(handles) {
            *slot = Some(h.join().expect("worker panicked"));
        }
    });
    out.into_iter().map(|x| x.unwrap()).collect()
}

/// Tree allreduce (sum) over per-worker vectors; returns the reduced
/// vector. All inputs must have equal length. log2(n) rounds, like a
/// binomial-tree reduce: pairs at distance 2^k combine each round.
pub fn allreduce_sum(mut shards: Vec<Vec<f32>>) -> Vec<f32> {
    assert!(!shards.is_empty());
    let len = shards[0].len();
    assert!(shards.iter().all(|s| s.len() == len), "ragged shards");
    let mut stride = 1;
    while stride < shards.len() {
        let mut i = 0;
        while i + stride < shards.len() {
            // Combine shard[i+stride] into shard[i].
            let (left, right) = shards.split_at_mut(i + stride);
            let dst = &mut left[i];
            let src = &right[0];
            for (a, b) in dst.iter_mut().zip(src) {
                *a += *b;
            }
            i += stride * 2;
        }
        stride *= 2;
    }
    shards.swap_remove(0)
}

/// Mean-reduce convenience used for gradient averaging across DP
/// workers.
pub fn allreduce_mean(shards: Vec<Vec<f32>>) -> Vec<f32> {
    let n = shards.len() as f32;
    let mut out = allreduce_sum(shards);
    if n > 1.0 {
        for x in &mut out {
            *x /= n;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scoped_map_ordered() {
        let out = scoped_map(4, |w| w * 10);
        assert_eq!(out, vec![0, 10, 20, 30]);
    }

    #[test]
    fn allreduce_sum_matches_naive() {
        for n in 1..=7 {
            let shards: Vec<Vec<f32>> = (0..n)
                .map(|w| (0..13).map(|i| (w * 13 + i) as f32).collect())
                .collect();
            let naive: Vec<f32> = (0..13)
                .map(|i| shards.iter().map(|s| s[i]).sum())
                .collect();
            let got = allreduce_sum(shards);
            assert_eq!(got, naive, "n={n}");
        }
    }

    #[test]
    fn allreduce_mean_averages() {
        let shards = vec![vec![1.0, 2.0], vec![3.0, 6.0]];
        assert_eq!(allreduce_mean(shards), vec![2.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_shards_rejected() {
        allreduce_sum(vec![vec![1.0], vec![1.0, 2.0]]);
    }

    #[test]
    fn parallel_map_actually_runs_closures() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let counter = AtomicUsize::new(0);
        scoped_map(8, |_| counter.fetch_add(1, Ordering::SeqCst));
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }
}
