//! Per-parameter compressibility probe: EMA-smoothed relative
//! detail-energy per candidate (basis, level).
//!
//! The raw statistic comes from the unified
//! [`WaveletBasis::lowpass_error_profile_into`] entry point — one
//! forward transform per candidate *basis* covers every candidate
//! *level* (the bands are nested), and the engine passes its
//! persistent row/scratch buffers, so a probe allocates nothing in
//! steady state. The EMA makes single noisy microbatches unable to
//! flip a selection on their own; the policy's hysteresis band
//! handles the remaining drift.
//!
//! Everything here is a pure function of the gradient bits, which is
//! what lets `optim::probe_bank` shard probing across workers under
//! the same fixed-boundary bit-identity contract as `step_bank`. The
//! profile's forward transforms run on the `wavelet::kernels`
//! dispatch table (SIMD where detected, bit-identical to scalar), so
//! probe results — and therefore adaptive selections and migration
//! timing — are unchanged by the `GWT_SIMD` setting.

use crate::wavelet::WaveletBasis;

/// EMA decay for the probe statistic. High enough that one outlier
/// microbatch cannot flip a selection, low enough that a regime
/// change (e.g. gradient noise decaying over training) is visible
/// within a few cadence windows.
pub const EMA_DECAY: f64 = 0.75;

/// EMA-smoothed per-candidate error fractions, parallel to the
/// engine's candidate list.
#[derive(Clone, Debug)]
pub struct ProbeEma {
    err: Vec<f64>,
    samples: usize,
}

impl ProbeEma {
    pub fn new(candidates: usize) -> ProbeEma {
        ProbeEma { err: vec![0.0; candidates], samples: 0 }
    }

    /// Fold one fresh measurement in. The first sample initializes
    /// the EMA directly (no zero-bias warmup to decay away).
    pub fn observe(&mut self, fresh: &[f64]) {
        assert_eq!(fresh.len(), self.err.len());
        if self.samples == 0 {
            self.err.copy_from_slice(fresh);
        } else {
            for (e, f) in self.err.iter_mut().zip(fresh) {
                *e = EMA_DECAY * *e + (1.0 - EMA_DECAY) * *f;
            }
        }
        self.samples += 1;
    }

    /// Smoothed errors — `None` until the first probe has landed (the
    /// policy skips parameters it has no statistics for).
    pub fn errors(&self) -> Option<Vec<f64>> {
        (self.samples > 0).then(|| self.err.clone())
    }

    pub fn samples(&self) -> usize {
        self.samples
    }
}

/// Fresh (un-smoothed) relative detail-energy for every
/// `(basis, level)` candidate of an `m × n` gradient, written into
/// `fresh` laid out level-major with [`WaveletBasis::ALL`] order
/// within a level (`fresh.len() == 2 * max_level`). `row_buf` and
/// `scratch` (len >= n) and `profile` (len == max_level) are
/// caller-owned so steady-state probing allocates nothing.
///
/// The fraction is `||g − P_l g||² / ||g||²` in `[0, 1]`; a zero
/// gradient reports 0 everywhere (perfectly compressible).
#[allow(clippy::too_many_arguments)]
pub fn candidate_errors(
    g: &[f32],
    m: usize,
    n: usize,
    max_level: usize,
    row_buf: &mut [f32],
    scratch: &mut [f32],
    profile: &mut [f64],
    fresh: &mut [f64],
) {
    assert_eq!(fresh.len(), WaveletBasis::ALL.len() * max_level);
    let total: f64 = g.iter().map(|v| (*v as f64).powi(2)).sum();
    if total <= 0.0 {
        fresh.fill(0.0);
        return;
    }
    for (bi, b) in WaveletBasis::ALL.iter().enumerate() {
        b.lowpass_error_profile_into(g, m, n, max_level, row_buf, scratch, profile);
        for l in 1..=max_level {
            let e = profile[l - 1];
            fresh[(l - 1) * WaveletBasis::ALL.len() + bi] =
                (e * e / total).min(1.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn errors_for(g: &[f32], m: usize, n: usize, max_level: usize) -> Vec<f64> {
        let mut row = vec![0.0f32; n];
        let mut scratch = vec![0.0f32; n];
        let mut profile = vec![0.0f64; max_level];
        let mut fresh = vec![0.0f64; 2 * max_level];
        candidate_errors(
            g, m, n, max_level, &mut row, &mut scratch, &mut profile, &mut fresh,
        );
        fresh
    }

    #[test]
    fn block_constant_gradient_is_fully_compressible_under_haar() {
        // Blocks of 2^3 identical values: zero Haar detail energy up
        // to level 3, strictly positive at level 4.
        let (m, n) = (4, 64);
        let mut rng = Rng::new(5);
        let mut g = vec![0.0f32; m * n];
        for r in 0..m {
            for blk in 0..n / 8 {
                let v = rng.normal_f32();
                for j in 0..8 {
                    g[r * n + blk * 8 + j] = v;
                }
            }
        }
        let fresh = errors_for(&g, m, n, 4);
        for l in 1..=3 {
            let haar = fresh[(l - 1) * 2];
            assert!(haar < 1e-9, "level {l}: {haar}");
        }
        assert!(fresh[3 * 2] > 0.01, "level 4 must lose energy");
        // Errors are monotone in level for each basis.
        for bi in 0..2 {
            for l in 1..4 {
                assert!(fresh[l * 2 + bi] >= fresh[(l - 1) * 2 + bi]);
            }
        }
    }

    #[test]
    fn white_noise_loses_about_half_per_level() {
        // E[detail fraction] at level l is 1 − 2^-l for white noise.
        let (m, n) = (64, 128);
        let g = Rng::new(9).normal_vec(m * n, 1.0);
        let fresh = errors_for(&g, m, n, 2);
        for bi in 0..2 {
            assert!((fresh[bi] - 0.5).abs() < 0.05, "l1 {}", fresh[bi]);
            assert!((fresh[2 + bi] - 0.75).abs() < 0.05, "l2 {}", fresh[2 + bi]);
        }
    }

    #[test]
    fn zero_gradient_reports_zero() {
        let g = vec![0.0f32; 32];
        assert!(errors_for(&g, 2, 16, 2).iter().all(|e| *e == 0.0));
    }

    #[test]
    fn ema_smooths_and_first_sample_initializes() {
        let mut ema = ProbeEma::new(2);
        assert!(ema.errors().is_none());
        ema.observe(&[0.8, 0.4]);
        assert_eq!(ema.errors().unwrap(), vec![0.8, 0.4]);
        ema.observe(&[0.0, 0.0]);
        let e = ema.errors().unwrap();
        assert!((e[0] - 0.8 * EMA_DECAY).abs() < 1e-12);
        assert!((e[1] - 0.4 * EMA_DECAY).abs() < 1e-12);
        assert_eq!(ema.samples(), 2);
    }
}
