#!/usr/bin/env bash
# CI gate for the GWT reproduction: build, tests, formatting, lints.
#
# Usage: ./ci.sh            # full gate
#        ./ci.sh --fast     # skip clippy/fmt (tier-1 only)
#
# The integration tests that need compiled HLO artifacts skip
# themselves when `artifacts/` is absent, so this runs green on a
# fresh checkout; run `make artifacts` first for full coverage.
set -euo pipefail
cd "$(dirname "$0")"

fast=0
[[ "${1:-}" == "--fast" ]] && fast=1

# Fail with a real message instead of "line 17: cargo: command not
# found" on hosts without the Rust toolchain (first observed running
# this script in a python-only container).
command -v cargo >/dev/null 2>&1 || {
    echo "ci.sh: cargo not found on PATH — install the Rust toolchain" \
         "or run inside the CI image" >&2
    exit 1
}

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# Second pass under the forced-rust GWT path: environments *with*
# artifacts would otherwise never exercise the HLO-less optimizer
# fallback (the env var is the legacy fallback spelling of the
# `gwt_path = rust` config key; see TrainConfig::resolve_gwt_path).
echo "== cargo test -q (GWT_OPT_PATH=rust) =="
GWT_OPT_PATH=rust cargo test -q

# Thread-matrix pass: the step-engine determinism contract at pinned
# worker counts. GWT_TEST_THREADS overrides the batteries' default
# {1,2,4,7} grid, so every CI run exercises the persistent StepPool,
# the legacy scoped-spawn baseline, and the sharded gradient
# accumulation at an explicit serial and an explicit odd-parallel
# count (odd counts catch uneven-chunk bugs).
for t in 1 7; do
    echo "== thread matrix (GWT_TEST_THREADS=$t) =="
    GWT_TEST_THREADS=$t cargo test -q \
        --test parallel_determinism --test grad_accum_parity
done

# SIMD-matrix pass: the wavelet kernel dispatch must be a pure
# throughput knob — `scalar` forces the portable kernels, `auto`
# picks the detected ISA (AVX2/NEON), and both must produce the same
# bits everywhere (the simd_kernels battery asserts this directly;
# parallel_determinism asserts it composes with pool sharding).
for simd in scalar auto; do
    echo "== simd matrix (GWT_SIMD=$simd) =="
    GWT_SIMD=$simd cargo test -q \
        --test simd_kernels --test parallel_determinism
done

# Smoke the pool-reuse bench rows: perf_hotpaths' dispatch-overhead,
# pool-vs-scoped bank-step, and serial-vs-sharded accumulation rows
# are artifact-free and print before the HLO gate, so this is green
# (and informative) on a fresh checkout.
#
# The run rewrites BENCH_perf_hotpaths.json in place, so snapshot the
# committed baseline first and gate the fresh medians against it
# afterwards (`gwt bench-check` skips itself while the committed file
# is still the empty-rows placeholder). GWT_BENCH_TOL widens/narrows
# the band (fractional; default +50% absorbs shared-runner noise).
bench_baseline=$(mktemp)
cp BENCH_perf_hotpaths.json "$bench_baseline"
echo "== pool-reuse bench rows (smoke) =="
GWT_BENCH_SCALE=0.2 cargo bench --bench perf_hotpaths

echo "== bench regression gate (perf_hotpaths) =="
cargo run --release -- bench-check "$bench_baseline" \
    BENCH_perf_hotpaths.json --tol "${GWT_BENCH_TOL:-0.5}"
rm -f "$bench_baseline"

# Fig-bench smokes, each under the same snapshot + bench-check gate
# as perf_hotpaths (the committed BENCH_*.json is the baseline; the
# gate skips itself while a file is still the empty-rows placeholder
# and compares only timing-formatted cells once recorded):
# * fig8 — Haar-vs-DB4 basis ablation (transform-level section is
#   artifact-free; error ratios gate on presence, not latency);
# * fig9 — composition grid, asserts analytic state bytes == measured
#   for every gwt-{haar,db4}-l x {adam,adam8bit,sgdm} pair and times
#   the bank step;
# * fig10 — adaptive compression (loss proxy, dynamics, probe
#   overhead), with in-bench asserts that adapt-fixed holds the gwt-2
#   footprint and adapt_budget_mb is a hard cap.
for fig in fig8_basis_ablation fig9_composition fig10_adaptive; do
    bench_baseline=$(mktemp)
    cp "BENCH_$fig.json" "$bench_baseline"
    echo "== $fig bench (smoke) =="
    GWT_BENCH_SCALE=0.2 cargo bench --bench "$fig"
    echo "== bench regression gate ($fig) =="
    cargo run --release -- bench-check "$bench_baseline" \
        "BENCH_$fig.json" --tol "${GWT_BENCH_TOL:-0.5}"
    rm -f "$bench_baseline"
done

# Job-engine smoke: two tiny synthetic jobs sharing one pool under a
# deliberately tight budget (1.2x the largest single-job charge), so
# the full-rank Adam job must queue behind the two gwt-2 jobs and be
# admitted when they finish — the admission path is exercised, not
# just the happy path. Artifact-free (--synthetic), run under both
# gwt_path settings like the e2e trainings below.
for path in auto rust; do
    echo "== job engine smoke (gwt_path=$path) =="
    out=$(cargo run --release -- serve --synthetic --budget-x 1.2 \
        -s gwt_path="$path" \
        "name=a,optimizer=gwt-2,steps=6" \
        "name=b,optimizer=gwt-2,steps=6,priority=1" \
        "name=c,optimizer=adam,steps=4" | tee /dev/stderr)
    grep -q "queued job 'c'" <<<"$out" \
        || { echo "job engine smoke: expected a queue event for 'c'"; exit 1; }
    grep -q "finished job 'c'" <<<"$out" \
        || { echo "job engine smoke: 'c' never finished"; exit 1; }
done

# Trace smoke: the observability loop end-to-end. A short synthetic
# traced serve run must stream a schema-valid events.jsonl
# (`trace check` validates the required keys of every line — the
# docs/observability.md compatibility contract) and render the
# summary report. Artifact-free.
trace_dir=$(mktemp -d)
echo "== trace smoke (--trace-dir) =="
out=$(cargo run --release -- serve --synthetic --trace-dir "$trace_dir" \
    "name=t,optimizer=gwt-2,steps=6" | tee /dev/stderr)
grep -q "finished job 't'" <<<"$out" \
    || { echo "trace smoke: job never finished"; exit 1; }
[[ -s "$trace_dir/events.jsonl" ]] \
    || { echo "trace smoke: no events.jsonl written"; exit 1; }
cargo run --release -- trace check "$trace_dir"
cargo run --release -- trace summary "$trace_dir" >/dev/null
rm -rf "$trace_dir"

# Replica-matrix smoke: the wavelet-domain DDP path end-to-end.
# `replicas=1` is the passthrough pin (no comm ledger); `replicas=4`
# runs the compressed approximation-band all-reduce and must report
# its communication volume (the "Nx vs full" multiple) in the per-job
# summary. Artifact-free, under both gwt_path settings like the rest.
for path in auto rust; do
    for r in 1 4; do
        echo "== replica matrix smoke (gwt_path=$path replicas=$r) =="
        out=$(cargo run --release -- serve --synthetic \
            -s gwt_path="$path" -s replicas="$r" \
            "name=r,optimizer=gwt-2,steps=6" | tee /dev/stderr)
        grep -q "finished job 'r'" <<<"$out" \
            || { echo "replica smoke: job never finished"; exit 1; }
        if [[ "$r" -gt 1 ]]; then
            grep -q "vs full" <<<"$out" \
                || { echo "replica smoke: expected a comm summary"; exit 1; }
        else
            grep -q "vs full" <<<"$out" \
                && { echo "replica smoke: single replica logged comm"; exit 1; }
        fi
    done
done

# Error-feedback smoke: the approx-band reduce with the residual
# delivery toggle on both settings, under both gwt_path settings.
# The comm summary must be present either way — EF never changes the
# wire bytes, only what lands in the detail positions (docs/ddp.md
# "Error feedback"); the `ddp_reduce=approx` spelling also exercises
# the `approx` alias of the default `auto`.
for path in auto rust; do
    for ef in on off; do
        echo "== error-feedback smoke (gwt_path=$path ddp_error_feedback=$ef) =="
        out=$(cargo run --release -- serve --synthetic \
            -s gwt_path="$path" -s replicas=4 \
            -s ddp_reduce=approx -s ddp_error_feedback="$ef" \
            "name=e,optimizer=gwt-2,steps=6" | tee /dev/stderr)
        grep -q "finished job 'e'" <<<"$out" \
            || { echo "ef smoke: job never finished"; exit 1; }
        grep -q "vs full" <<<"$out" \
            || { echo "ef smoke: expected a comm summary"; exit 1; }
    done
done

# Composed-spec e2e: one previously unreachable composition
# (wavelet-compressed 8-bit Adam) trains via its CLI spec string,
# under both gwt_path settings (the knob must be inert for non-Adam
# inners — no HLO artifact exists for them — but both routes must
# train). Needs compiled artifacts for the train_step executable.
if [[ -f artifacts/manifest.json ]]; then
    for path in auto rust; do
        echo "== composed e2e: gwt-db4-1+adam8bit (gwt_path=$path) =="
        cargo run --release -- train \
            -s preset=nano -s optimizer=gwt-db4-1+adam8bit \
            -s steps=20 -s eval_every=10 -s gwt_path="$path"
    done
    # Replicated e2e: 4 logical replicas over disjoint PJRT data
    # shards, combined through the approximation-band all-reduce
    # (`--replicas` is the CLI spelling of the `replicas` config key).
    echo "== replicated e2e: gwt-2 --replicas 4 =="
    cargo run --release -- train --replicas 4 \
        -s preset=nano -s optimizer=gwt-2 -s steps=12 -s eval_every=6
    # Adaptive e2e: probe + policy + migration in a real training
    # loop, under both gwt_path settings (the knob is inert for
    # adaptive specs — they always run the rust paths, since HLO
    # artifacts are keyed by the (basis, level) a migration changes —
    # but both routes must train and report the adapt summary).
    for path in auto rust; do
        echo "== adaptive e2e: adapt-greedy+adam (gwt_path=$path) =="
        cargo run --release -- train \
            -s preset=nano -s optimizer=adapt-greedy+adam \
            -s steps=30 -s adapt_cadence=10 -s eval_every=15 \
            -s gwt_path="$path"
    done
else
    echo "== composed e2e skipped (no artifacts/; run 'make artifacts') =="
fi

if [[ "$fast" == 0 ]]; then
    echo "== cargo fmt --check =="
    cargo fmt --check

    echo "== cargo clippy -- -D warnings =="
    cargo clippy --all-targets -- -D warnings
fi

echo "CI OK"
