//! The `<transform>+<inner>` spec grammar: parse/label round-trip
//! over the full composition grid, legacy-alias equivalence, and
//! precise parse errors on junk. These strings are user-facing at
//! three surfaces — CLI `-s optimizer=...`, config files, and
//! checkpoint/curve labels — so the round-trip property is a
//! compatibility contract, not a convenience.

use gwt::adapt::AdaptPolicy;
use gwt::config::{InnerSpec, OptSpec, TransformSpec};
use gwt::wavelet::WaveletBasis;

fn all_transforms() -> Vec<TransformSpec> {
    let mut out = vec![TransformSpec::Identity];
    for basis in WaveletBasis::ALL {
        for level in 1..=3 {
            out.push(TransformSpec::wavelet(basis, level));
        }
    }
    for denom in [4, 8] {
        out.push(TransformSpec::LowRank { rank_denom: denom });
        out.push(TransformSpec::RandomProj { rank_denom: denom });
    }
    for policy in AdaptPolicy::ALL {
        out.push(TransformSpec::Adaptive { policy });
    }
    out
}

const ALL_INNERS: [InnerSpec; 4] = [
    InnerSpec::Adam,
    InnerSpec::Adam8bit,
    InnerSpec::AdamMini,
    InnerSpec::SgdM,
];

#[test]
fn label_parse_roundtrip_over_the_full_grid() {
    for t in all_transforms() {
        for i in ALL_INNERS {
            let spec = OptSpec::composed(t, i);
            let label = spec.label();
            let back = OptSpec::parse(&label)
                .unwrap_or_else(|e| panic!("label '{label}' did not parse: {e:#}"));
            assert_eq!(back, spec, "round-trip failed for '{label}'");
            // Labels are also case-stable through the parser.
            assert_eq!(OptSpec::parse(&label.to_lowercase()).unwrap(), spec);
            assert_eq!(OptSpec::parse(&label.to_uppercase()).unwrap(), spec);
        }
    }
    // Standalone specs round-trip too.
    for spec in [OptSpec::Muon, OptSpec::lora(4), OptSpec::lora(64)] {
        assert_eq!(OptSpec::parse(&spec.label()).unwrap(), spec);
    }
}

#[test]
fn explicit_plus_form_always_parses() {
    // Even when the label uses a legacy spelling (`GWT-2`, `Adam`),
    // the fully explicit `<transform>+<inner>` spelling is accepted.
    for t in all_transforms() {
        for i in ALL_INNERS {
            let t_tok = match t {
                TransformSpec::Identity => "id".to_string(),
                other => other.label().to_lowercase(),
            };
            let i_tok = i.label().to_lowercase();
            let s = format!("{t_tok}+{i_tok}");
            assert_eq!(
                OptSpec::parse(&s).unwrap(),
                OptSpec::composed(t, i),
                "explicit form '{s}'"
            );
        }
    }
}

#[test]
fn legacy_aliases_equal_adam_inner_compositions() {
    for (legacy, explicit) in [
        ("gwt-2", "gwt-2+adam"),
        ("gwt-db4-3", "gwt-db4-3+adam"),
        ("gwt-haar-2", "gwt-2+adam"),
        ("galore-4", "galore-4+adam"),
        ("galore-1/4", "galore-4+adam"),
        ("apollo-8", "apollo-1/8+adam"),
        ("adam", "id+adam"),
        ("adam8bit", "identity+adam8bit"),
        ("8bit-adam", "id+8bit-adam"),
        ("adam-mini", "id+adammini"),
        ("sgdm", "full+sgd-m"),
        ("sgd", "id+sgdm"),
    ] {
        assert_eq!(
            OptSpec::parse(legacy).unwrap(),
            OptSpec::parse(explicit).unwrap(),
            "{legacy} vs {explicit}"
        );
    }
    // And the aliases hit the intended constructors.
    assert_eq!(OptSpec::parse("gwt-2").unwrap(), OptSpec::gwt(2));
    assert_eq!(
        OptSpec::parse("gwt-db4-2").unwrap(),
        OptSpec::gwt_basis(WaveletBasis::Db4, 2)
    );
    assert_eq!(OptSpec::parse("galore-1/4").unwrap(), OptSpec::galore(4));
    assert_eq!(OptSpec::parse("apollo-1/4").unwrap(), OptSpec::apollo(4));
    assert_eq!(OptSpec::parse("adam").unwrap(), OptSpec::adam());
    assert_eq!(OptSpec::parse("lora-1/4").unwrap(), OptSpec::lora(4));
}

#[test]
fn junk_specs_fail_with_precise_messages() {
    let err = |s: &str| format!("{:#}", OptSpec::parse(s).unwrap_err());

    // Dangling '+' on either side.
    assert!(err("gwt-2+").contains("missing inner optimizer"), "{}", err("gwt-2+"));
    assert!(err("+adam").contains("missing gradient transform"), "{}", err("+adam"));
    assert!(err("+").contains("missing gradient transform"));

    // A transform in inner position names the mistake.
    let e = err("gwt-2+galore-4");
    assert!(e.contains("'galore-4'") && e.contains("not an inner optimizer"), "{e}");
    let e = err("gwt-2+gwt-3");
    assert!(e.contains("not an inner optimizer"), "{e}");

    // An inner in transform position names the mistake the other way.
    let e = err("adam+adam8bit");
    assert!(e.contains("'adam'") && e.contains("not a gradient transform"), "{e}");

    // Standalone optimizers refuse to compose, in either position.
    assert!(err("gwt-2+muon").contains("standalone"));
    assert!(err("muon+adam").contains("standalone"));
    assert!(err("lora-1/4+adam").contains("standalone"));
    assert!(err("gwt-2+lora-1/4").contains("standalone"));

    // Arity and payload errors.
    assert!(err("gwt-2+adam+sgdm").contains("exactly one '+'"));
    assert!(err("gwt-x+adam").contains("gwt level"));
    assert!(err("galore-0+adam").contains("positive"));
    assert!(err("gwt-2+frobnicate").contains("unknown inner optimizer"));
    assert!(err("frobnicate+adam").contains("unknown gradient transform"));
    assert!(err("frobnicate").contains("unknown optimizer spec"));

    // Adaptive tokens: unknown policies are named precisely, and an
    // adaptive transform in inner position points the right way.
    let e = err("adapt-warp+adam");
    assert!(e.contains("unknown adapt policy 'warp'"), "{e}");
    assert!(e.contains("fixed, greedy, anneal"), "{e}");
    assert!(err("adapt-+adam").contains("unknown adapt policy"), "{}", err("adapt-+adam"));
    assert!(err("adapt-warp").contains("unknown adapt policy"));
    let e = err("gwt-2+adapt-greedy");
    assert!(e.contains("not an inner optimizer"), "{e}");
    assert!(err("adapt-greedy+muon").contains("standalone"));
}

#[test]
fn adaptive_spec_aliases_and_roundtrip() {
    // `adapt` defaults to greedy; the policy's long spellings from
    // the issue (`greedy-threshold`, `anneal-up`) are aliases.
    for (legacy, explicit) in [
        ("adapt", "adapt-greedy+adam"),
        ("adapt-greedy", "adapt-greedy+adam"),
        ("adapt-greedy-threshold", "adapt-greedy+adam"),
        ("adapt-anneal-up+sgdm", "adapt-anneal+sgdm"),
        ("adapt-fixed", "adapt-fixed+adam"),
    ] {
        assert_eq!(
            OptSpec::parse(legacy).unwrap(),
            OptSpec::parse(explicit).unwrap(),
            "{legacy} vs {explicit}"
        );
    }
    for policy in AdaptPolicy::ALL {
        for inner in ALL_INNERS {
            let spec = OptSpec::composed(
                TransformSpec::Adaptive { policy },
                inner,
            );
            assert_eq!(OptSpec::parse(&spec.label()).unwrap(), spec);
        }
    }
    assert_eq!(
        OptSpec::adaptive(AdaptPolicy::Greedy).label(),
        "Adapt-Greedy"
    );
    assert_eq!(
        OptSpec::parse("adapt-fixed+adam8bit").unwrap().label(),
        "Adapt-Fixed+8bit-Adam"
    );
}

#[test]
fn summary_and_trainer_labels_roundtrip() {
    // The `summary()` / checkpoint-facing spelling is the label — it
    // must parse back to the configured spec for every composition.
    use gwt::config::TrainConfig;
    for spec in [
        OptSpec::gwt(2),
        OptSpec::parse("gwt-db4-2+adam8bit").unwrap(),
        OptSpec::parse("galore-4+sgdm").unwrap(),
        OptSpec::adam8bit(),
        OptSpec::Muon,
    ] {
        let cfg = TrainConfig { optimizer: spec, ..Default::default() };
        let shown = cfg.summary()["optimizer"].clone();
        assert_eq!(OptSpec::parse(&shown).unwrap(), spec, "summary '{shown}'");
    }
}
