//! Test-support substrate: approx assertions and a tiny property-test
//! driver (no proptest in this image). `prop_check` runs a closure
//! over `cases` seeded inputs and reports the first failing seed so
//! failures reproduce deterministically.

use crate::rng::Rng;

/// Assert two slices are elementwise close (absolute + relative).
#[track_caller]
pub fn approx_eq_slice(got: &[f32], want: &[f32], tol: f32) {
    assert_eq!(got.len(), want.len(), "length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let diff = (g - w).abs();
        let bound = tol + tol * w.abs();
        assert!(
            diff <= bound,
            "index {i}: got {g}, want {w} (diff {diff} > {bound})"
        );
    }
}

#[track_caller]
pub fn approx_eq(got: f32, want: f32, tol: f32) {
    let diff = (got - want).abs();
    assert!(
        diff <= tol + tol * want.abs(),
        "got {got}, want {want} (diff {diff})"
    );
}

/// Run `f` for `cases` independent seeds; panic with the failing seed.
/// The closure receives a fresh `Rng` per case — draw whatever shaped
/// inputs the property needs from it.
#[track_caller]
pub fn prop_check<F: FnMut(&mut Rng) -> Result<(), String>>(
    name: &str,
    cases: usize,
    mut f: F,
) {
    for case in 0..cases {
        let seed = 0x9e3779b97f4a7c15u64
            .wrapping_mul(case as u64 + 1)
            .wrapping_add(0xabcd);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Worker-count grid for the step-engine determinism batteries. The
/// default {1, 2, 4, 7} covers serial, even, and odd sharding; CI's
/// thread-matrix pass pins a single count via the `GWT_TEST_THREADS`
/// env var (a comma-separated list is also accepted), so the contract
/// is exercised at explicit counts on every run without the tests
/// hardcoding them.
///
/// A set-but-invalid value (unparseable entry, or 0 — there is no
/// "auto" here) panics instead of silently running the default grid:
/// a pin that doesn't pin would let CI go green while never
/// exercising the requested count.
pub fn test_thread_grid() -> Vec<usize> {
    match std::env::var("GWT_TEST_THREADS") {
        Ok(raw) => raw
            .split(',')
            .map(|t| match t.trim().parse::<usize>() {
                Ok(n) if n > 0 => n,
                _ => panic!(
                    "GWT_TEST_THREADS must be a comma-separated list of \
                     positive worker counts, got '{raw}'"
                ),
            })
            .collect(),
        Err(_) => vec![1, 2, 4, 7],
    }
}

/// Helper: random matrix dims with width divisible by 2^max_level.
pub fn rand_dims(rng: &mut Rng, max_level: usize) -> (usize, usize, usize) {
    let m = 1 + rng.usize_below(48);
    let level = 1 + rng.usize_below(max_level);
    let blocks = 1 + rng.usize_below(16);
    let n = blocks << level;
    (m, n, level)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_check_passes_trivial_property() {
        prop_check("uniform in range", 50, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn prop_check_reports_failure() {
        prop_check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn thread_grid_is_nonempty_and_positive() {
        // Env-agnostic invariants (CI pins GWT_TEST_THREADS, so the
        // exact grid is not asserted here).
        let g = test_thread_grid();
        assert!(!g.is_empty());
        assert!(g.iter().all(|&n| n > 0));
    }

    #[test]
    fn rand_dims_divisible() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let (m, n, level) = rand_dims(&mut rng, 4);
            assert!(m >= 1 && n >= 2);
            assert_eq!(n % (1 << level), 0);
        }
    }
}
