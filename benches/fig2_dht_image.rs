//! Paper Fig 2: a 2-level DHT on an image — the approximation
//! coefficients at 25% size preserve the key structure. We build a
//! synthetic image (smooth background + rectangles + texture), keep
//! only A2, reconstruct, and report PSNR + energy retention.

use gwt::bench_harness::{write_result, TableView};
use gwt::rng::Rng;
use gwt::wavelet::{haar_fwd, haar_inv, haar_lowpass};

fn synth_image(h: usize, w: usize, rng: &mut Rng) -> Vec<f32> {
    let mut img = vec![0.0f32; h * w];
    // Smooth background.
    for i in 0..h {
        for j in 0..w {
            img[i * w + j] = 0.5
                + 0.3 * ((i as f32 / h as f32) * std::f32::consts::PI).sin()
                + 0.2 * ((j as f32 / w as f32) * 2.0 * std::f32::consts::PI).cos();
        }
    }
    // Rectangles ("key structural features").
    for (r0, c0, r1, c1, v) in
        [(8, 8, 24, 40, 1.0f32), (32, 16, 56, 28, 0.0), (40, 40, 60, 60, 0.8)]
    {
        for i in r0..r1.min(h) {
            for j in c0..c1.min(w) {
                img[i * w + j] = v;
            }
        }
    }
    // Fine texture (what the detail bands carry).
    for px in img.iter_mut() {
        *px += 0.02 * rng.normal_f32();
    }
    img
}

fn psnr(a: &[f32], b: &[f32]) -> f64 {
    let mse: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.len() as f64;
    10.0 * (1.0f64 / mse.max(1e-12)).log10()
}

fn main() -> anyhow::Result<()> {
    let (h, w) = (64usize, 64usize);
    let mut rng = Rng::new(2);
    let img = synth_image(h, w, &mut rng);

    let mut table = TableView::new(
        "Fig 2 — 2-level DHT on a synthetic image",
        &["level", "kept coeffs", "size", "PSNR (dB)", "energy kept"],
    );
    let energy = |x: &[f32]| -> f64 {
        x.iter().map(|v| (*v as f64) * (*v as f64)).sum()
    };
    for level in [1usize, 2, 3] {
        // 2-D Haar: rows then columns (separable).
        let rows = haar_fwd(&img, h, w, level);
        let cols_t = gwt::linalg::transpose(&rows, h, w);
        let both_t = haar_fwd(&cols_t, w, h, level);
        let coeffs = gwt::linalg::transpose(&both_t, w, h);
        // Zero all but the A_l x A_l corner, invert.
        let (qh, qw) = (h >> level, w >> level);
        let mut kept = vec![0.0f32; h * w];
        for i in 0..qh {
            for j in 0..qw {
                kept[i * w + j] = coeffs[i * w + j];
            }
        }
        let kept_energy = energy(&kept) / energy(&coeffs);
        let t = gwt::linalg::transpose(&kept, h, w);
        let it = haar_inv(&t, w, h, level);
        let back_rows = gwt::linalg::transpose(&it, w, h);
        let recon = haar_inv(&back_rows, h, w, level);
        let p = psnr(&img, &recon);
        table.row(vec![
            format!("{level}"),
            format!("{}x{}", qh, qw),
            format!("{:.1}%", 100.0 / 4f64.powi(level as i32)),
            format!("{:.1}", p),
            format!("{:.1}%", 100.0 * kept_energy),
        ]);
        if level == 2 {
            // The figure's claim: 25%-size approximation preserves
            // structure => high energy retention and usable PSNR.
            assert!(kept_energy > 0.95, "A2 energy only {kept_energy}");
            assert!(p > 15.0, "PSNR {p} too low for 'preserved structure'");
        }
    }
    table.print();
    println!("(1-D column low-pass P_l is the same operator the GWT optimizer uses)");

    // Cross-check: zeroing details == block-mean operator (1-D).
    let row = &img[..w];
    let lp = haar_lowpass(row, 1, w, 2);
    let mut c = haar_fwd(row, 1, w, 2);
    for v in c[w >> 2..].iter_mut() {
        *v = 0.0;
    }
    let via = haar_inv(&c, 1, w, 2);
    gwt::testing::approx_eq_slice(&via, &lp, 1e-5);

    write_result("fig2_dht_image", &table, vec![])?;
    Ok(())
}
