//! 8-bit Adam (Dettmers et al. 2021): Adam whose M/V states are kept
//! block-quantized (int8 + per-block absmax scale). Reproduces both
//! the memory footprint and the quantize/dequantize cost that makes
//! it the slowest method in the paper's Table III throughput column.

use super::{AdamHp, MatrixOpt};
use crate::tensor::Tensor;

pub const BLOCK: usize = 2048;

/// One quantized state tensor.
struct QState {
    q: Vec<i8>,
    scales: Vec<f32>,
}

impl QState {
    fn zeros(n: usize) -> Self {
        QState { q: vec![0; n], scales: vec![0.0; n.div_ceil(BLOCK)] }
    }

    /// Nonlinear (square-root) code map, like bitsandbytes' dynamic
    /// quantization: resolution concentrates near zero, which keeps
    /// small second-moment entries from collapsing to 0 (a linear map
    /// makes Adam unstable — denominators snap to eps).
    fn dequant(&self, out: &mut [f32]) {
        for (bi, chunk) in self.q.chunks(BLOCK).enumerate() {
            let s = self.scales[bi];
            let base = bi * BLOCK;
            for (j, &qv) in chunk.iter().enumerate() {
                let r = qv as f32 / 127.0;
                out[base + j] = r.signum() * r * r * s;
            }
        }
    }

    fn quant(&mut self, x: &[f32]) {
        for (bi, chunk) in x.chunks(BLOCK).enumerate() {
            let absmax = chunk.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            self.scales[bi] = absmax;
            let inv = if absmax > 0.0 { 1.0 / absmax } else { 0.0 };
            let base = bi * BLOCK;
            for (j, &v) in chunk.iter().enumerate() {
                let r = (v * inv).clamp(-1.0, 1.0);
                let code = r.signum() * r.abs().sqrt() * 127.0;
                self.q[base + j] = code.round().clamp(-127.0, 127.0) as i8;
            }
        }
    }

    fn bytes(&self) -> usize {
        self.q.len() + self.scales.len() * 4
    }
}

pub struct Adam8bit {
    hp: AdamHp,
    m: QState,
    v: QState,
    t: usize,
    shape: Vec<usize>,
    /// Reused dequant scratch (kept out of state accounting — it's
    /// transient like the paper's dequant workspace).
    scratch_m: Vec<f32>,
    scratch_v: Vec<f32>,
}

impl Adam8bit {
    pub fn new(shape: &[usize], hp: AdamHp) -> Self {
        let n: usize = shape.iter().product();
        Adam8bit {
            hp,
            m: QState::zeros(n),
            v: QState::zeros(n),
            t: 0,
            shape: shape.to_vec(),
            scratch_m: vec![0.0; n],
            scratch_v: vec![0.0; n],
        }
    }
}

impl MatrixOpt for Adam8bit {
    fn direction(&mut self, g: &Tensor, _lr_eff: f32) -> Tensor {
        assert_eq!(g.shape(), &self.shape[..]);
        self.t += 1;
        let bc = self.hp.bias_correction(self.t);
        let (b1, b2, eps) = (self.hp.beta1, self.hp.beta2, self.hp.eps);
        self.m.dequant(&mut self.scratch_m);
        self.v.dequant(&mut self.scratch_v);
        let mut out = vec![0.0f32; g.len()];
        for i in 0..g.len() {
            let gi = g.data()[i];
            self.scratch_m[i] = b1 * self.scratch_m[i] + (1.0 - b1) * gi;
            // v is non-negative; quantization keeps sign structure.
            self.scratch_v[i] = b2 * self.scratch_v[i] + (1.0 - b2) * gi * gi;
            out[i] = bc * self.scratch_m[i] / (self.scratch_v[i].sqrt() + eps);
        }
        self.m.quant(&self.scratch_m);
        self.v.quant(&self.scratch_v);
        Tensor::new(&self.shape, out)
    }

    fn state_bytes(&self) -> usize {
        self.m.bytes() + self.v.bytes()
    }

    fn label(&self) -> String {
        "8bit-Adam".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn quant_roundtrip_error_bounded() {
        let mut rng = Rng::new(1);
        let x: Vec<f32> = rng.normal_vec(5000, 0.1);
        let mut q = QState::zeros(5000);
        q.quant(&x);
        let mut back = vec![0.0f32; 5000];
        q.dequant(&mut back);
        let absmax = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        for (a, b) in x.iter().zip(&back) {
            // sqrt code map: absolute error grows with |x|; bound by
            // the local derivative 2*sqrt(|x|*absmax)/127 + half-step.
            let bound = 2.0 * (a.abs() * absmax).sqrt() / 127.0
                + absmax / (127.0 * 127.0)
                + 1e-7;
            assert!((a - b).abs() <= bound, "x={a} back={b} bound={bound}");
        }
    }

    #[test]
    fn state_bytes_are_quarter_of_f32_adam() {
        let a8 = Adam8bit::new(&[64, 64], AdamHp::default());
        let a32 = super::super::Adam::new(&[64, 64], AdamHp::default());
        let ratio = a8.state_bytes() as f64 / a32.state_bytes() as f64;
        assert!(ratio < 0.27, "ratio {ratio}");
    }

    #[test]
    fn tracks_full_precision_adam_closely() {
        let mut rng = Rng::new(2);
        let mut a8 = Adam8bit::new(&[32], AdamHp::default());
        let mut a32 = super::super::Adam::new(&[32], AdamHp::default());
        let mut max_rel = 0.0f32;
        for _ in 0..20 {
            let g = Tensor::randn(&[32], 1.0, &mut rng);
            let u8v = a8.direction(&g, 0.0);
            let u32v = a32.direction(&g, 0.0);
            for (a, b) in u8v.data().iter().zip(u32v.data()) {
                let rel = (a - b).abs() / (b.abs() + 0.1);
                max_rel = max_rel.max(rel);
            }
        }
        assert!(max_rel < 0.25, "divergence {max_rel}");
    }
}
