//! Data pipeline: synthetic corpus generation, byte-level tokenizer,
//! sharded batching. Stands in for the paper's C4 English corpus (see
//! DESIGN.md substitution table): what the optimizer comparison needs
//! is a next-token task with learnable structure, which the Markov
//! word-model below provides (per-token entropy well under log|V|).

pub mod corpus;
pub mod loader;

pub use corpus::{CorpusSpec, SyntheticCorpus};
pub use loader::{Batch, DataLoader, Split};

/// Byte-level tokenizer. Ids 0 (pad) and 1 (mask) are reserved; the
/// corpus generator only emits printable ASCII so the reservation is
/// structural, not enforced per call.
#[derive(Clone, Copy, Debug, Default)]
pub struct ByteTokenizer;

pub const PAD_ID: i32 = 0;
pub const MASK_ID: i32 = 1; // mirrors model.py BERT_MASK_ID

impl ByteTokenizer {
    pub fn vocab_size(&self) -> usize {
        256
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| b as i32).collect()
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .filter_map(|&i| {
                if (2..256).contains(&i) {
                    Some(i as u8 as char)
                } else {
                    None
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenizer_roundtrip_ascii() {
        let t = ByteTokenizer;
        let text = "the quick brown fox.";
        let ids = t.encode(text);
        assert_eq!(ids.len(), text.len());
        assert_eq!(t.decode(&ids), text);
    }

    #[test]
    fn decode_skips_reserved() {
        let t = ByteTokenizer;
        assert_eq!(t.decode(&[PAD_ID, 104, 105, MASK_ID]), "hi");
    }
}
