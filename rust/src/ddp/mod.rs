//! Wavelet-domain data-parallel replicas: compressed all-reduce over
//! the approximation band.
//!
//! The GWT paper frames wavelet subspaces as *scalable* state
//! compression; this module makes the same decomposition serve
//! communication. R logical model replicas each consume their own
//! data shard and produce a full gradient per step. Instead of
//! all-reducing full-width gradients and letting each optimizer
//! re-derive its coefficients (transform → reduce → inverse →
//! re-forward), the reducer applies the forward transform **once per
//! replica**, tree-all-reduces only the retained approximation band
//! (`n >> level` of `n` columns — a `2^level`× payload reduction),
//! and feeds the reduced coefficients straight into the optimizer's
//! coefficient-domain step entry
//! ([`MatrixOpt::coeff_band`][crate::optim::MatrixOpt::coeff_band] /
//! `direction_from_coeffs`). Detail bands are *dropped* (zeroed), the
//! communication-side analogue of the optimizer keeping moments only
//! over the approximation band.
//!
//! ## Determinism contract
//!
//! Everything here is pinned bit-identical (rust/tests/
//! ddp_determinism.rs) along three axes:
//!
//! * **R = 1** is a pure passthrough — `GradReducer` plans nothing,
//!   delegates to [`combine_grads`], and logs no traffic, so a
//!   1-replica job is bit-identical to the plain trainer loop.
//! * **Full-band mode** (`ddp_reduce = full`, or any parameter whose
//!   optimizer exposes no coefficient seam) delegates to the exact
//!   [`combine_grads`] tree — bitwise the legacy `dp_workers` path.
//! * **Thread/SIMD invariance**: the per-replica forward transform is
//!   row-sharded with fixed `chunk_bounds` boundaries and per-row
//!   independence, and the cross-replica reduction replays
//!   `pool::allreduce_sum`'s documented binomial tree per element
//!   ([`allreduce_mean_sharded`]) with replicas in fixed ascending
//!   index order — so worker count and `GWT_SIMD` mode never change a
//!   bit.
//!
//! ## Error feedback
//!
//! Dropping detail bands is a *biased* compressor: their gradient
//! energy never reaches the optimizer. With `ddp_error_feedback = on`
//! (and `ddp_reduce = auto`/`approx`, R > 1, a non-adaptive plan),
//! each replica keeps the detail bands its previous combine dropped
//! and the next combine tree-averages those saved residuals into the
//! output's detail positions — delayed delivery, one combine late,
//! instead of never. The wire payload and ledger charges are
//! unchanged (the residual exchange rides the in-process shared
//! address space); the first EF-on combine is bitwise the EF-off
//! combine (zero residuals). See [`ef`] and docs/ddp.md.
//!
//! ## Adaptive specs reduce full-band
//!
//! `adapt-*` optimizers could step from coefficients (the seam exists
//! on `AdaptiveWavelet`), but their probe consumes the *weight-domain*
//! gradient stream: an approximation-band-only reduce would feed the
//! probe zero detail energy, making every candidate level look
//! perfectly compressible and the policy self-reinforce deeper
//! levels. [`GradReducer::plan`] therefore pins adaptive configs to
//! the full-band path; see docs/ddp.md.
//!
//! ## Communication accounting
//!
//! A tree all-reduce over R shards moves `R-1` payload-sized messages
//! (one per tree edge), so the reducer charges
//! `(R-1) · payload_elems · 4` bytes per parameter per combine, and
//! the counterfactual `(R-1) · numel · 4` to `full_bytes`. Per-step
//! totals land in [`CommLog`] (flushed by [`GradReducer::log_step`]);
//! `serve` surfaces them per job.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};

use anyhow::Result;

use crate::config::{DdpReduce, TrainConfig, TransformSpec};
use crate::coordinator::dp::combine_grads;
use crate::memory::ParamShape;
use crate::metrics::{CommLog, CommRecord};
use crate::optim::ParamOptimizer;
use crate::pool::{allreduce_mean, allreduce_mean_sharded, Sharding};
use crate::tensor::Tensor;
use crate::wavelet::WaveletBasis;

pub mod ef;

pub use ef::ErrorFeedback;

/// One parameter's reduction plan when the compressed path is on:
/// which decomposition to transform into, and the matrix geometry
/// (the flat gradient is `rows × cols` row-major).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BandPlan {
    pub basis: WaveletBasis,
    pub level: usize,
    pub rows: usize,
    pub cols: usize,
}

impl BandPlan {
    /// Approximation-band width per row.
    pub fn approx_cols(&self) -> usize {
        self.cols >> self.level
    }
}

/// The cross-replica gradient reducer: owns the reduce-mode decision,
/// the per-parameter band plans, and the communication ledger.
pub struct GradReducer {
    replicas: usize,
    reduce: DdpReduce,
    /// Adaptive specs are pinned to full-band (see module docs).
    adaptive: bool,
    /// Residual store when `ddp_error_feedback` is on and the config
    /// can plan at all (R > 1, not full-band, not adaptive); `None`
    /// keeps the EF-off combine byte-for-byte today's path.
    ef: Option<ErrorFeedback>,
    /// Warn-once latch for the non-matrix plan fallback ([`plan`]
    /// takes `&self`).
    warned_non_matrix: AtomicBool,
    pending_bytes: usize,
    pending_full_bytes: usize,
    pub comm: CommLog,
}

impl GradReducer {
    pub fn new(cfg: &TrainConfig) -> GradReducer {
        let adaptive = matches!(
            cfg.optimizer.transform(),
            Some(TransformSpec::Adaptive { .. })
        );
        let ef = (cfg.ddp_error_feedback
            && cfg.replicas > 1
            && cfg.ddp_reduce != DdpReduce::Full
            && !adaptive)
            .then(|| ErrorFeedback::new(cfg.replicas));
        GradReducer {
            replicas: cfg.replicas,
            reduce: cfg.ddp_reduce,
            adaptive,
            ef,
            warned_non_matrix: AtomicBool::new(false),
            pending_bytes: 0,
            pending_full_bytes: 0,
            comm: CommLog::default(),
        }
    }

    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Resolve the per-parameter reduction plan against the current
    /// bank. `None` entries reduce full-band; `Some` entries reduce
    /// the approximation band of that decomposition. Resolved once
    /// per optimizer step (migrations happen *post*-step, so a plan
    /// never straddles a decomposition change — and adaptive configs
    /// are all-`None` anyway).
    pub fn plan(
        &self,
        bank: &[ParamOptimizer],
        shapes: &[ParamShape],
    ) -> Vec<Option<BandPlan>> {
        assert_eq!(bank.len(), shapes.len(), "bank/shapes length mismatch");
        if self.replicas <= 1
            || self.reduce == DdpReduce::Full
            || self.adaptive
        {
            return vec![None; bank.len()];
        }
        bank.iter()
            .zip(shapes)
            .map(|(opt, p)| {
                let (basis, level) = opt.coeff_band()?;
                // The coefficient seam only exists on matrix (2-D)
                // engines. A non-2D shape here means the bank and the
                // shapes list drifted — a bug, but one that must not
                // corrupt the reduce: a debug_assert alone would let
                // release builds misread rows/cols into a garbage
                // BandPlan. Fall back to full-band and say so once.
                if p.shape.len() != 2 {
                    if !self.warned_non_matrix.swap(true, Ordering::Relaxed) {
                        eprintln!(
                            "[ddp] param '{}' exposes a coefficient seam \
                             but has a {}-D shape; reducing it full-band",
                            p.name,
                            p.shape.len()
                        );
                    }
                    return None;
                }
                Some(BandPlan {
                    basis,
                    level,
                    rows: p.shape[0],
                    cols: p.shape[1],
                })
            })
            .collect()
    }

    /// Combine per-replica per-param gradients under `plan`. Input
    /// and output match [`combine_grads`]: `worker_grads[r][p]` flat
    /// data in, averaged `[p]` out — except that `Some`-planned
    /// parameters come back as *coefficient* tensors (approximation
    /// band populated, detail bands zero) for
    /// [`crate::optim::step_bank_mixed`] to route through the bank's
    /// coefficient entries.
    ///
    /// An all-`None` plan delegates wholesale to [`combine_grads`],
    /// which is what guarantees full-band mode reproduces the legacy
    /// path bit for bit.
    /// [`GradReducer::combine`] under a `band_reduce` span (the
    /// per-row forward transforms inside it additionally record into
    /// the process-global `forward_transform` aggregate, see
    /// [`approx_forward`]). The span only brackets the call — the
    /// reduction is byte-for-byte the plain path.
    pub fn combine_obs(
        &mut self,
        worker_grads: Vec<Vec<Vec<f32>>>,
        plan: &[Option<BandPlan>],
        sharding: &Sharding,
        step: usize,
        obs: &mut crate::obs::JobObs,
    ) -> Result<Vec<Vec<f32>>> {
        let t0 = obs.begin();
        let out = self.combine(worker_grads, plan, sharding);
        obs.end(crate::obs::Phase::BandReduce, t0, step);
        out
    }

    pub fn combine(
        &mut self,
        worker_grads: Vec<Vec<Vec<f32>>>,
        plan: &[Option<BandPlan>],
        sharding: &Sharding,
    ) -> Result<Vec<Vec<f32>>> {
        let r = worker_grads.len();
        if r <= 1 || plan.iter().all(|p| p.is_none()) {
            let full_elems: usize = worker_grads
                .first()
                .map(|w| w.iter().map(|g| g.len()).sum())
                .unwrap_or(0);
            let out = combine_grads(worker_grads)?;
            if r > 1 {
                let moved = (r - 1) * full_elems * 4;
                self.pending_bytes += moved;
                self.pending_full_bytes += moved;
            }
            return Ok(out);
        }
        // Mixed path: same topology validation as `combine_grads`,
        // same error wording, so callers see one contract.
        let n_params = worker_grads[0].len();
        anyhow::ensure!(
            plan.len() == n_params,
            "GradReducer::combine: plan covers {} params, workers produced \
             {n_params}",
            plan.len()
        );
        for (w, grads) in worker_grads.iter().enumerate() {
            if grads.len() != n_params {
                anyhow::bail!(
                    "combine_grads: ragged input — worker {w} produced {} \
                     param gradients, worker 0 produced {n_params}",
                    grads.len()
                );
            }
            for (p, g) in grads.iter().enumerate() {
                let want = worker_grads[0][p].len();
                if g.len() != want {
                    anyhow::bail!(
                        "combine_grads: ragged input — worker {w} param {p} \
                         has {} elements, worker 0 has {want}",
                        g.len()
                    );
                }
            }
        }
        let mut out = Vec::with_capacity(n_params);
        let mut per_worker: Vec<std::vec::IntoIter<Vec<f32>>> =
            worker_grads.into_iter().map(|w| w.into_iter()).collect();
        for (idx, bp) in plan.iter().take(n_params).enumerate() {
            // Replica shards in fixed ascending index order — the
            // order `allreduce_sum`'s tree contract is defined over.
            let shards: Vec<Vec<f32>> =
                per_worker.iter_mut().map(|it| it.next().unwrap()).collect();
            match bp {
                None => {
                    let numel = shards[0].len();
                    self.pending_bytes += (r - 1) * numel * 4;
                    self.pending_full_bytes += (r - 1) * numel * 4;
                    out.push(allreduce_mean(shards));
                }
                Some(bp) => {
                    let numel = shards[0].len();
                    anyhow::ensure!(
                        numel == bp.rows * bp.cols,
                        "GradReducer::combine: param is {numel} elements, \
                         plan says {}x{}",
                        bp.rows,
                        bp.cols
                    );
                    let q = bp.approx_cols();
                    // Ledger charges are identical with and without
                    // error feedback: only the approximation band
                    // crosses the wire either way (the residual
                    // exchange is in-process, see module docs).
                    self.pending_bytes += (r - 1) * bp.rows * q * 4;
                    self.pending_full_bytes += (r - 1) * numel * 4;
                    match &mut self.ef {
                        None => {
                            let compact = approx_reduce(
                                sharding, bp.basis, bp.level, &shards,
                                bp.rows, bp.cols,
                            );
                            // Scatter the reduced band into a zeroed
                            // full coefficient tensor ([A_l | 0 … 0]
                            // per row): detail bands are dropped, by
                            // design.
                            let mut coeffs = vec![0.0f32; numel];
                            for (crow, arow) in coeffs
                                .chunks_exact_mut(bp.cols)
                                .zip(compact.chunks_exact(q))
                            {
                                crow[..q].copy_from_slice(arow);
                            }
                            out.push(coeffs);
                        }
                        Some(ef) => {
                            out.push(ef_reduce(
                                sharding, ef, idx, bp, &shards,
                            ));
                        }
                    }
                }
            }
        }
        Ok(out)
    }

    /// Whether error-feedback residual buffers are live on this
    /// reducer (config on *and* the mode can plan at all).
    pub fn ef_enabled(&self) -> bool {
        self.ef.is_some()
    }

    /// Measured bytes of live residual state (0 before the first
    /// planned combine, and always 0 with EF off).
    pub fn ef_state_bytes(&self) -> usize {
        self.ef.as_ref().map_or(0, |e| e.state_bytes())
    }

    /// Global L2 norm of the stored residuals, for the obs gauge.
    pub fn ef_residual_norm(&self) -> f64 {
        self.ef.as_ref().map_or(0.0, |e| e.residual_norm())
    }

    /// Residual buffers as checkpoint tensors
    /// (`ddp::ef::{param-name}::{replica}`), empty with EF off — the
    /// serve snapshot seam merges these into the job's state map.
    pub fn export_ef_state(
        &self,
        shapes: &[ParamShape],
    ) -> Vec<(String, Tensor)> {
        self.ef
            .as_ref()
            .map_or_else(Vec::new, |e| e.export_state(shapes))
    }

    /// Restore residual buffers from a checkpoint state map. A no-op
    /// with EF off (foreign `ddp::ef::*` keys are simply unused) and
    /// for maps without EF keys (buffers stay zero — the EF-off-
    /// compatible cold start).
    pub fn import_ef_state(
        &mut self,
        state: &BTreeMap<String, Tensor>,
        shapes: &[ParamShape],
    ) -> Result<()> {
        match &mut self.ef {
            Some(e) => e.import_state(state, shapes),
            None => Ok(()),
        }
    }

    /// Flush the traffic accumulated by [`GradReducer::combine`]
    /// since the last flush into the ledger as one per-step record
    /// (gradient accumulation folds its microbatch combines into that
    /// step's record). No-op when nothing moved (R = 1).
    pub fn log_step(&mut self, step: usize) {
        if self.pending_full_bytes == 0 {
            return;
        }
        self.comm.push(CommRecord {
            step,
            full_bytes: self.pending_full_bytes,
            bytes: self.pending_bytes,
        });
        self.pending_bytes = 0;
        self.pending_full_bytes = 0;
    }
}

/// Forward-transform each row of the flat `rows × cols` gradient and
/// keep only the approximation band: returns `rows × (cols >> level)`
/// compact data. Row-sharded over `sharding` with per-worker
/// persistent `(row, scratch)` buffers; each row's transform is the
/// same `fwd_row` call at any worker count, so the output is
/// bit-identical across the thread grid (and across `GWT_SIMD` modes,
/// by the kernel tables' own bit-identity contract).
fn approx_forward(
    sharding: &Sharding,
    basis: WaveletBasis,
    level: usize,
    g: &[f32],
    rows: usize,
    cols: usize,
) -> Vec<f32> {
    forward_rows(sharding, basis, level, g, rows, cols, cols >> level)
}

/// Shared transform core: forward-transform each row, keep the first
/// `keep` coefficients. `keep = cols >> level` is the EF-off
/// approximation band; `keep = cols` is the EF path's full
/// coefficient tensor. Same `fwd_row` kernel call per row in both, so
/// the first `cols >> level` output columns are bit-identical across
/// the two widths — which is what keeps the EF-on wire band byte-for-
/// byte the EF-off wire band.
fn forward_rows(
    sharding: &Sharding,
    basis: WaveletBasis,
    level: usize,
    g: &[f32],
    rows: usize,
    cols: usize,
    keep: usize,
) -> Vec<f32> {
    assert_eq!(g.len(), rows * cols, "gradient/geometry mismatch");
    // Global span: this runs per replica per parameter, below the
    // per-job seam (one relaxed-bool check when tracing is off).
    let span = crate::obs::timing_start();
    let mut compact = vec![0.0f32; rows * keep];
    let mut items: Vec<_> = g
        .chunks_exact(cols)
        .zip(compact.chunks_exact_mut(keep))
        .collect();
    sharding.run_chunks_mut(
        &mut items,
        |_| (vec![0.0f32; cols], vec![0.0f32; cols]),
        |(row, scratch), _, chunk| {
            for (gr, ar) in chunk.iter_mut() {
                row.copy_from_slice(gr);
                basis.fwd_row(row, level, scratch);
                ar.copy_from_slice(&row[..keep]);
            }
        },
    );
    crate::obs::record_global(crate::obs::Phase::ForwardTransform, span);
    compact
}

/// EF-on combine for one planned parameter (module docs §Error
/// feedback): full forward per replica, approximation-band tree-mean
/// on the wire exactly as EF-off, *previous* residual tree-mean into
/// the detail positions, then overwrite each replica's residual with
/// the detail bands this combine dropped. Zero-initialized residuals
/// make the first combine bitwise the EF-off combine; both reductions
/// ride [`allreduce_mean_sharded`]'s fixed ascending-replica tree, so
/// the output is pinned across the thread/SIMD grid like everything
/// else here.
fn ef_reduce(
    sharding: &Sharding,
    ef: &mut ErrorFeedback,
    idx: usize,
    bp: &BandPlan,
    shards: &[Vec<f32>],
) -> Vec<f32> {
    let (rows, cols, q) = (bp.rows, bp.cols, bp.approx_cols());
    let dw = cols - q;
    ef.ensure(idx, rows, dw);
    let full: Vec<Vec<f32>> = shards
        .iter()
        .map(|g| forward_rows(sharding, bp.basis, bp.level, g, rows, cols, cols))
        .collect();
    let bands: Vec<Vec<f32>> = full
        .iter()
        .map(|c| {
            let mut b = vec![0.0f32; rows * q];
            for (br, cr) in
                b.chunks_exact_mut(q).zip(c.chunks_exact(cols))
            {
                br.copy_from_slice(&cr[..q]);
            }
            b
        })
        .collect();
    let band_mean = allreduce_mean_sharded(sharding, &bands);
    // Delayed delivery: the detail bands dropped by the *previous*
    // combine, averaged in the same fixed replica order.
    let detail_mean = allreduce_mean_sharded(sharding, ef.residuals(idx));
    for (r, coeffs) in full.iter().enumerate() {
        ef.capture(idx, r, coeffs, cols, q);
    }
    let mut out = vec![0.0f32; rows * cols];
    for ((crow, arow), drow) in out
        .chunks_exact_mut(cols)
        .zip(band_mean.chunks_exact(q))
        .zip(detail_mean.chunks_exact(dw))
    {
        crow[..q].copy_from_slice(arow);
        crow[q..].copy_from_slice(drow);
    }
    out
}

/// The compressed all-reduce primitive: transform each replica's
/// `rows × cols` gradient, tree-average the approximation bands in
/// replica-index order, return the `rows × (cols >> level)` compact
/// mean. Public for the perf_hotpaths bench (full-band vs approx-band
/// bytes/latency rows).
pub fn approx_reduce(
    sharding: &Sharding,
    basis: WaveletBasis,
    level: usize,
    shards: &[Vec<f32>],
    rows: usize,
    cols: usize,
) -> Vec<f32> {
    let bands: Vec<Vec<f32>> = shards
        .iter()
        .map(|g| approx_forward(sharding, basis, level, g, rows, cols))
        .collect();
    allreduce_mean_sharded(sharding, &bands)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OptSpec;
    use crate::optim::build_optimizers_sharded;
    use crate::rng::Rng;

    fn shapes() -> Vec<ParamShape> {
        vec![
            ParamShape {
                name: "blk.attn".into(),
                shape: vec![8, 64],
                eligible: true,
            },
            ParamShape { name: "norm".into(), shape: vec![16], eligible: false },
        ]
    }

    fn cfg(optimizer: &str, replicas: usize) -> TrainConfig {
        TrainConfig {
            optimizer: OptSpec::parse(optimizer).unwrap(),
            replicas,
            ..Default::default()
        }
    }

    fn bank(cfg: &TrainConfig) -> Vec<ParamOptimizer> {
        build_optimizers_sharded(&shapes(), cfg, None, Sharding::Serial)
            .unwrap()
    }

    #[test]
    fn plan_is_empty_for_single_replica_full_mode_and_adaptive() {
        for (spec, replicas, reduce) in [
            ("gwt-2", 1, DdpReduce::Auto),
            ("gwt-2", 4, DdpReduce::Full),
            ("adapt-greedy", 4, DdpReduce::Auto),
        ] {
            let mut c = cfg(spec, replicas);
            c.ddp_reduce = reduce;
            let r = GradReducer::new(&c);
            let plan = r.plan(&bank(&c), &shapes());
            assert!(plan.iter().all(|p| p.is_none()), "{spec} R={replicas}");
        }
    }

    #[test]
    fn plan_reads_the_coefficient_seam_per_param() {
        let c = cfg("gwt-db4-2", 4);
        let r = GradReducer::new(&c);
        let plan = r.plan(&bank(&c), &shapes());
        assert_eq!(
            plan[0],
            Some(BandPlan {
                basis: WaveletBasis::Db4,
                level: 2,
                rows: 8,
                cols: 64,
            })
        );
        // Non-eligible params (identity transform) reduce full-band.
        assert_eq!(plan[1], None);
        // Composed Wavelet×inner engines expose the seam through the
        // generic path now, so they plan too.
        for spec in ["gwt-2+adam8bit", "gwt-2+adam-mini", "gwt-2+sgdm"] {
            let c8 = cfg(spec, 4);
            let plan8 = GradReducer::new(&c8).plan(&bank(&c8), &shapes());
            assert_eq!(
                plan8[0],
                Some(BandPlan {
                    basis: WaveletBasis::Haar,
                    level: 2,
                    rows: 8,
                    cols: 64,
                }),
                "{spec}"
            );
            assert_eq!(plan8[1], None, "{spec}");
        }
    }

    #[test]
    fn non_matrix_param_never_gets_a_plan() {
        // Regression: `plan` used to guard the 2-D requirement with a
        // debug_assert only — a release build handed a non-matrix
        // param a garbage BandPlan (rows/cols misread from a 1-D
        // shape) and silently corrupted the reduce. Doctor the shapes
        // list so the seam-exposing first entry reports 1-D with the
        // same numel; the plan must fall back to full-band in every
        // build profile.
        let c = cfg("gwt-2", 4);
        let b = bank(&c);
        let mut doctored = shapes();
        doctored[0].shape = vec![512];
        let r = GradReducer::new(&c);
        let plan = r.plan(&b, &doctored);
        assert!(plan.iter().all(|p| p.is_none()));
        // Warn-once latch: a second resolve stays quiet and planless.
        assert!(r.plan(&b, &doctored).iter().all(|p| p.is_none()));
        // The genuine 2-D shapes still plan with the same reducer.
        assert!(r.plan(&b, &shapes())[0].is_some());
    }

    #[test]
    fn all_none_plan_is_combine_grads_bitwise() {
        let mut rng = Rng::new(0xdd9);
        let worker_grads: Vec<Vec<Vec<f32>>> = (0..3)
            .map(|_| vec![rng.normal_vec(512, 1.0), rng.normal_vec(16, 1.0)])
            .collect();
        let want = combine_grads(worker_grads.clone()).unwrap();
        let c = cfg("gwt-2", 3);
        let mut r = GradReducer::new(&c);
        let got = r
            .combine(worker_grads, &[None, None], &Sharding::Serial)
            .unwrap();
        for (g, w) in got.iter().zip(&want) {
            let gb: Vec<u32> = g.iter().map(|x| x.to_bits()).collect();
            let wb: Vec<u32> = w.iter().map(|x| x.to_bits()).collect();
            assert_eq!(gb, wb);
        }
        // Full-band traffic: (R-1) · Σnumel · 4 bytes, ratio 1.
        r.log_step(1);
        assert_eq!(r.comm.total_full_bytes(), 2 * (512 + 16) * 4);
        assert_eq!(r.comm.total_bytes(), 2 * (512 + 16) * 4);
    }

    #[test]
    fn approx_plan_reduces_band_and_zeroes_details() {
        let mut rng = Rng::new(0xdda);
        let (rows, cols, level) = (4usize, 32usize, 2usize);
        let q = cols >> level;
        let shards: Vec<Vec<f32>> =
            (0..2).map(|_| rng.normal_vec(rows * cols, 1.0)).collect();
        let bp = BandPlan { basis: WaveletBasis::Haar, level, rows, cols };
        let c = cfg("gwt-2", 2);
        let mut r = GradReducer::new(&c);
        let worker_grads: Vec<Vec<Vec<f32>>> =
            shards.iter().map(|s| vec![s.clone()]).collect();
        let out = r
            .combine(worker_grads, &[Some(bp)], &Sharding::Serial)
            .unwrap();
        // Reference: mean of the two full forward transforms' bands
        // (2 shards: tree order == plain pairwise add).
        let f0 = WaveletBasis::Haar.fwd(&shards[0], rows, cols, level);
        let f1 = WaveletBasis::Haar.fwd(&shards[1], rows, cols, level);
        for row in 0..rows {
            for j in 0..cols {
                let idx = row * cols + j;
                if j < q {
                    let want = (f0[idx] + f1[idx]) / 2.0;
                    assert_eq!(out[0][idx].to_bits(), want.to_bits());
                } else {
                    assert_eq!(out[0][idx], 0.0, "detail band not zeroed");
                }
            }
        }
        r.log_step(1);
        assert_eq!(r.comm.total_full_bytes(), rows * cols * 4);
        assert_eq!(r.comm.total_bytes(), rows * q * 4);
        assert_eq!(r.comm.compression_ratio().unwrap(), 4.0);
    }

    #[test]
    fn ragged_input_keeps_combine_grads_wording() {
        let c = cfg("gwt-2", 2);
        let mut r = GradReducer::new(&c);
        let w0 = vec![vec![1.0f32; 32], vec![2.0f32; 4]];
        let w1 = vec![vec![1.0f32; 32]];
        let bp = BandPlan {
            basis: WaveletBasis::Haar,
            level: 1,
            rows: 1,
            cols: 32,
        };
        let err = r
            .combine(vec![w0, w1], &[Some(bp), None], &Sharding::Serial)
            .unwrap_err()
            .to_string();
        assert!(err.contains("ragged input"), "{err}");
        assert!(err.contains("worker 1"), "{err}");
    }

    #[test]
    fn empty_worker_grads_error_cleanly_and_charge_nothing() {
        // Ledger edge case: zero replicas takes the quick path, where
        // the byte charge reads worker 0's payload — it must charge 0
        // and surface `combine_grads`' own error, not panic on the
        // missing first worker.
        let c = cfg("gwt-2", 2);
        let mut r = GradReducer::new(&c);
        let err = r
            .combine(Vec::new(), &[None], &Sharding::Serial)
            .unwrap_err()
            .to_string();
        assert!(err.contains("no worker gradients"), "{err}");
        r.log_step(1);
        assert!(r.comm.records.is_empty());
        assert!(r.comm.compression_ratio().is_none());
    }

    #[test]
    fn single_replica_logs_no_traffic() {
        let c = cfg("gwt-2", 1);
        let mut r = GradReducer::new(&c);
        let out = r
            .combine(
                vec![vec![vec![1.0, 2.0, 3.0, 4.0]]],
                &[None],
                &Sharding::Serial,
            )
            .unwrap();
        assert_eq!(out[0], vec![1.0, 2.0, 3.0, 4.0]);
        r.log_step(1);
        assert!(r.comm.records.is_empty());
    }

    #[test]
    fn approx_reduce_is_sharding_invariant() {
        let mut rng = Rng::new(0xddb);
        let (rows, cols, level) = (16usize, 64usize, 2usize);
        let shards: Vec<Vec<f32>> =
            (0..4).map(|_| rng.normal_vec(rows * cols, 1.0)).collect();
        let want: Vec<u32> = approx_reduce(
            &Sharding::Serial,
            WaveletBasis::Haar,
            level,
            &shards,
            rows,
            cols,
        )
        .iter()
        .map(|x| x.to_bits())
        .collect();
        for sharding in [Sharding::Scoped(3), Sharding::pool(4)] {
            let got: Vec<u32> = approx_reduce(
                &sharding,
                WaveletBasis::Haar,
                level,
                &shards,
                rows,
                cols,
            )
            .iter()
            .map(|x| x.to_bits())
            .collect();
            assert_eq!(got, want, "{sharding:?}");
        }
    }

    fn ef_cfg(replicas: usize) -> TrainConfig {
        let mut c = cfg("gwt-2", replicas);
        c.ddp_error_feedback = true;
        c
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn ef_is_inert_when_the_mode_cannot_plan() {
        // Full-band mode, single replica, and adaptive specs never
        // build residual buffers even with the key on.
        let mut c = ef_cfg(4);
        c.ddp_reduce = DdpReduce::Full;
        assert!(!GradReducer::new(&c).ef_enabled());
        assert!(!GradReducer::new(&ef_cfg(1)).ef_enabled());
        let mut c = ef_cfg(4);
        c.optimizer = OptSpec::parse("adapt-greedy").unwrap();
        assert!(!GradReducer::new(&c).ef_enabled());
        assert!(GradReducer::new(&ef_cfg(4)).ef_enabled());
    }

    #[test]
    fn ef_first_combine_is_bitwise_ef_off() {
        let mut rng = Rng::new(0xddc);
        let (rows, cols, level) = (4usize, 32usize, 2usize);
        let bp = BandPlan { basis: WaveletBasis::Haar, level, rows, cols };
        let worker_grads: Vec<Vec<Vec<f32>>> = (0..3)
            .map(|_| vec![rng.normal_vec(rows * cols, 1.0)])
            .collect();
        let mut off = GradReducer::new(&cfg("gwt-2", 3));
        let mut on = GradReducer::new(&ef_cfg(3));
        assert!(on.ef_enabled() && !off.ef_enabled());
        let a = off
            .combine(worker_grads.clone(), &[Some(bp)], &Sharding::Serial)
            .unwrap();
        let b = on
            .combine(worker_grads, &[Some(bp)], &Sharding::Serial)
            .unwrap();
        // Zero residuals: the delivered detail mean is exactly the
        // zeros the EF-off path scatters.
        assert_eq!(bits(&a[0]), bits(&b[0]));
        // Ledger identical too — EF moves no extra wire bytes.
        off.log_step(1);
        on.log_step(1);
        assert_eq!(off.comm.total_bytes(), on.comm.total_bytes());
        assert_eq!(off.comm.total_full_bytes(), on.comm.total_full_bytes());
    }

    #[test]
    fn ef_second_combine_delivers_previous_detail_mean() {
        let mut rng = Rng::new(0xddd);
        let (rows, cols, level) = (4usize, 32usize, 2usize);
        let q = cols >> level;
        let bp = BandPlan { basis: WaveletBasis::Haar, level, rows, cols };
        let g1: Vec<Vec<f32>> =
            (0..2).map(|_| rng.normal_vec(rows * cols, 1.0)).collect();
        let g2: Vec<Vec<f32>> =
            (0..2).map(|_| rng.normal_vec(rows * cols, 1.0)).collect();
        let mut r = GradReducer::new(&ef_cfg(2));
        r.combine(
            g1.iter().map(|g| vec![g.clone()]).collect(),
            &[Some(bp)],
            &Sharding::Serial,
        )
        .unwrap();
        assert_eq!(r.ef_state_bytes(), 2 * rows * (cols - q) * 4);
        assert!(r.ef_residual_norm() > 0.0);
        let out = r
            .combine(
                g2.iter().map(|g| vec![g.clone()]).collect(),
                &[Some(bp)],
                &Sharding::Serial,
            )
            .unwrap();
        // Reference: approx band is the mean of fwd(g2) bands; detail
        // positions carry the mean of fwd(g1) details — delivered one
        // combine late (2 shards: tree order == plain pairwise add).
        let f1: Vec<Vec<f32>> = g1
            .iter()
            .map(|g| WaveletBasis::Haar.fwd(g, rows, cols, level))
            .collect();
        let f2: Vec<Vec<f32>> = g2
            .iter()
            .map(|g| WaveletBasis::Haar.fwd(g, rows, cols, level))
            .collect();
        for row in 0..rows {
            for j in 0..cols {
                let idx = row * cols + j;
                let want = if j < q {
                    (f2[0][idx] + f2[1][idx]) / 2.0
                } else {
                    (f1[0][idx] + f1[1][idx]) / 2.0
                };
                assert_eq!(
                    out[0][idx].to_bits(),
                    want.to_bits(),
                    "row {row} col {j}"
                );
            }
        }
    }

    #[test]
    fn ef_state_roundtrips_through_the_checkpoint_seam() {
        let mut rng = Rng::new(0xdde);
        let (rows, cols) = (8usize, 64usize);
        let bp = BandPlan {
            basis: WaveletBasis::Haar,
            level: 2,
            rows,
            cols,
        };
        // shapes()[0] is the 8×64 matrix the plan covers; the norm
        // param reduces full-band alongside it.
        let plan = [Some(bp), None];
        let mk_round = |rng: &mut Rng| -> Vec<Vec<Vec<f32>>> {
            (0..2)
                .map(|_| {
                    vec![
                        rng.normal_vec(rows * cols, 1.0),
                        rng.normal_vec(16, 1.0),
                    ]
                })
                .collect()
        };
        let mut a = GradReducer::new(&ef_cfg(2));
        a.combine(mk_round(&mut rng), &plan, &Sharding::Serial).unwrap();
        assert!(a.ef_state_bytes() > 0);
        // Export → import into a fresh reducer: one tensor per
        // replica for the planned param, none for the norm param.
        let state: BTreeMap<String, Tensor> =
            a.export_ef_state(&shapes()).into_iter().collect();
        assert_eq!(state.len(), 2);
        assert!(state.contains_key("ddp::ef::blk.attn::0"));
        let mut b = GradReducer::new(&ef_cfg(2));
        b.import_ef_state(&state, &shapes()).unwrap();
        assert_eq!(b.ef_state_bytes(), a.ef_state_bytes());
        // The next combine is bit-identical from either reducer.
        let round = mk_round(&mut rng);
        let ax = a.combine(round.clone(), &plan, &Sharding::Serial).unwrap();
        let bx = b.combine(round, &plan, &Sharding::Serial).unwrap();
        for (x, y) in ax.iter().zip(&bx) {
            assert_eq!(bits(x), bits(y));
        }
        // EF-off reducers export nothing and import as a no-op.
        let mut off = GradReducer::new(&cfg("gwt-2", 2));
        assert!(off.export_ef_state(&shapes()).is_empty());
        off.import_ef_state(&state, &shapes()).unwrap();
        assert_eq!(off.ef_state_bytes(), 0);
    }

    #[test]
    fn ef_combine_is_sharding_invariant() {
        let mut rng = Rng::new(0xddf);
        let (rows, cols) = (16usize, 64usize);
        let bp = BandPlan {
            basis: WaveletBasis::Haar,
            level: 2,
            rows,
            cols,
        };
        let rounds: Vec<Vec<Vec<Vec<f32>>>> = (0..2)
            .map(|_| {
                (0..4)
                    .map(|_| vec![rng.normal_vec(rows * cols, 1.0)])
                    .collect()
            })
            .collect();
        let mut want = Vec::new();
        {
            let mut r = GradReducer::new(&ef_cfg(4));
            for round in &rounds {
                let out = r
                    .combine(round.clone(), &[Some(bp)], &Sharding::Serial)
                    .unwrap();
                want.push(bits(&out[0]));
            }
        }
        for sharding in [Sharding::Scoped(3), Sharding::pool(4)] {
            let mut r = GradReducer::new(&ef_cfg(4));
            for (round, w) in rounds.iter().zip(&want) {
                let out = r
                    .combine(round.clone(), &[Some(bp)], &sharding)
                    .unwrap();
                assert_eq!(&bits(&out[0]), w, "{sharding:?}");
            }
        }
    }
}
