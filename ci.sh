#!/usr/bin/env bash
# CI gate for the GWT reproduction: build, tests, formatting, lints.
#
# Usage: ./ci.sh            # full gate
#        ./ci.sh --fast     # skip clippy/fmt (tier-1 only)
#
# The integration tests that need compiled HLO artifacts skip
# themselves when `artifacts/` is absent, so this runs green on a
# fresh checkout; run `make artifacts` first for full coverage.
set -euo pipefail
cd "$(dirname "$0")"

fast=0
[[ "${1:-}" == "--fast" ]] && fast=1

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if [[ "$fast" == 0 ]]; then
    echo "== cargo fmt --check =="
    cargo fmt --check

    echo "== cargo clippy -- -D warnings =="
    cargo clippy --all-targets -- -D warnings
fi

echo "CI OK"
