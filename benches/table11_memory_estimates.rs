//! Paper Table XI: weight/optimizer-state memory estimates for every
//! method on every paper model — fully analytic, compared against the
//! paper's published numbers row by row.

use gwt::bench_harness::{write_result, TableView};
use gwt::config::OptSpec;
use gwt::memory::{account, MemoryReport, PAPER_MODELS};

/// Paper Table XI state-memory values (GB) per model, in column order
/// 60M / 130M / 350M / 1B.
const PAPER_STATES: &[(&str, [f64; 4])] = &[
    ("Full-Rank Adam", [0.23, 0.51, 1.37, 5.20]),
    ("MUON", [0.19, 0.38, 0.86, 3.61]),
    ("GaLore-1/4", [0.17, 0.32, 0.70, 2.16]),
    ("APOLLO-1/4", [0.17, 0.32, 0.70, 2.16]),
    ("GWT-2", [0.16, 0.29, 0.56, 1.81]),
    ("GaLore-1/8", [0.15, 0.27, 0.55, 1.55]),
    ("APOLLO-1/8", [0.15, 0.27, 0.55, 1.55]),
    ("GWT-3", [0.14, 0.25, 0.41, 1.20]),
];

fn method_for(name: &str) -> OptSpec {
    match name {
        "Full-Rank Adam" => OptSpec::adam(),
        "MUON" => OptSpec::Muon,
        "GaLore-1/4" => OptSpec::galore(4),
        "APOLLO-1/4" => OptSpec::apollo(4),
        "GWT-2" => OptSpec::gwt(2),
        "GaLore-1/8" => OptSpec::galore(8),
        "APOLLO-1/8" => OptSpec::apollo(8),
        "GWT-3" => OptSpec::gwt(3),
        _ => unreachable!(),
    }
}

fn main() -> anyhow::Result<()> {
    let mut table = TableView::new(
        "Table XI — optimizer-state memory, ours vs paper (GB)",
        &[
            "method", "60M", "paper", "130M", "paper", "350M", "paper",
            "1B", "paper", "max rel err",
        ],
    );
    let mut worst = 0.0f64;
    for (name, paper) in PAPER_STATES {
        let mut row = vec![name.to_string()];
        let mut max_rel = 0.0f64;
        for (i, pm) in PAPER_MODELS.iter().take(4).enumerate() {
            let gb =
                MemoryReport::gb(account(&pm.params(), method_for(name)).state_bytes);
            let rel = (gb - paper[i]).abs() / paper[i];
            max_rel = max_rel.max(rel);
            row.push(format!("{gb:.2}"));
            row.push(format!("{:.2}", paper[i]));
        }
        row.push(format!("{:.0}%", max_rel * 100.0));
        table.row(row);
        worst = worst.max(max_rel);
    }
    table.print();
    println!(
        "worst relative deviation from the paper's table: {:.0}% [{}]",
        worst * 100.0,
        if worst < 0.25 { "OK (<25%)" } else { "MISS" }
    );
    // Residual deviations trace to the paper's own Table VIII/XI
    // inconsistencies (e.g. the 1B layer count) and unstated extras
    // in its MUON/1-per-8 rows; orderings match exactly.
    assert!(worst < 0.25, "memory model drifted from the paper");

    // Weight memory column (identical across methods except LoRA).
    let mut wtable = TableView::new(
        "Table XI (weights) — model weight memory (GB)",
        &["model", "weights", "paper"],
    );
    let paper_weights = [0.11f64, 0.26, 0.68, 2.60];
    for (pm, pw) in PAPER_MODELS.iter().take(4).zip(paper_weights) {
        let gb = MemoryReport::gb(account(&pm.params(), OptSpec::adam()).weight_bytes);
        wtable.row(vec![
            pm.name.to_string(),
            format!("{gb:.2}"),
            format!("{pw:.2}"),
        ]);
    }
    wtable.print();
    write_result("table11_memory_estimates", &table, vec![])?;
    Ok(())
}
