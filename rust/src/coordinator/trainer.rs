//! The pre-training client: a thin single-job wrapper over
//! `serve::JobState` + `serve::PretrainSource`. The step-loop math
//! lives in `JobState::step_once`; this type owns what is specific to
//! a one-job CLI run — the runtime handle, the eval executable, the
//! run loop, and params-only checkpoints. Bit-identity with the
//! pre-refactor monolithic Trainer is pinned by
//! `rust/tests/job_engine.rs`.

use std::sync::Arc;

use anyhow::Result;

use crate::config::{presets, TrainConfig};
use crate::data::DataLoader;
use crate::memory::ParamShape;
use crate::metrics::LossCurve;
use crate::pool::Sharding;
use crate::runtime::{
    literal_f32, literal_tokens, scalar_from_literal, Runtime,
};
use crate::serve::{JobState, PretrainSource};
use crate::tensor::Tensor;

pub struct Trainer {
    runtime: Arc<Runtime>,
    preset: &'static presets::ModelPreset,
    /// Step-engine dispatcher, built once from `cfg.threads`: a
    /// persistent `pool::StepPool` whose workers are reused by every
    /// `step_bank`/`probe_bank`/grad-accumulate call of the run
    /// (`Serial` when the run is single-threaded).
    sharding: Sharding,
    /// The job core: params, bank, schedule, curve, adapt controller.
    pub job: JobState,
    /// §Perf L3-2: executable resolved once at construction instead
    /// of a key-format + map lookup on every eval batch.
    eval_exec: Arc<crate::runtime::Exec>,
}

/// Summary of a finished run (consumed by benches / examples).
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub label: String,
    pub final_loss: f32,
    pub final_ppl: f32,
    pub valid_loss: f32,
    pub valid_ppl: f32,
    pub tokens_per_sec: f64,
    pub state_bytes: usize,
    pub curve: LossCurve,
}

impl Trainer {
    pub fn new(
        runtime: Arc<Runtime>,
        cfg: TrainConfig,
        loader: &DataLoader,
    ) -> Result<Trainer> {
        cfg.validate()?;
        let preset = presets::find(&cfg.preset)?;
        // One pool for the whole run: bank stepping, probing, grad
        // accumulation, and (single-param banks) row sharding all
        // reuse these workers.
        let sharding = Sharding::pool(cfg.resolve_threads());
        let source = PretrainSource::new(&runtime, &cfg, loader)?;
        let eval_exec = runtime.exec(&format!("eval_loss_{}", cfg.preset))?;
        let job = JobState::new(
            cfg,
            Box::new(source),
            Some(runtime.clone()),
            &sharding,
        )?;
        Ok(Trainer { runtime, preset, sharding, job, eval_exec })
    }

    pub fn preset(&self) -> &'static presets::ModelPreset {
        self.preset
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.runtime
    }

    pub fn shapes(&self) -> &[ParamShape] {
        &self.job.shapes
    }

    pub fn optimizer_state_bytes(&self) -> usize {
        self.job.optimizer_state_bytes()
    }

    /// One optimizer step: grad_accum x dp_workers microbatches.
    pub fn train_step(&mut self) -> Result<f32> {
        self.job.step_once(&self.sharding)
    }

    /// Mean validation loss via the `eval_loss` artifact.
    pub fn eval_loss(&self, loader: &DataLoader, max_batches: usize) -> Result<f32> {
        let exec = &self.eval_exec;
        let batches = loader.valid_batches(max_batches);
        anyhow::ensure!(!batches.is_empty(), "no validation batches");
        let mut total = 0.0f32;
        for b in &batches {
            let mut inputs = Vec::with_capacity(self.job.params.len() + 1);
            for p in &self.job.params {
                inputs.push(literal_f32(p)?);
            }
            inputs.push(literal_tokens(
                &b.tokens,
                self.preset.batch,
                self.preset.seq_len,
            )?);
            let outs = exec.run(&inputs)?;
            total += scalar_from_literal(&outs[0])?;
        }
        Ok(total / batches.len() as f32)
    }

    /// Run the configured number of steps; returns the outcome
    /// summary. `verbose` prints a progress line every `eval_every`.
    pub fn run(&mut self, loader: &DataLoader, verbose: bool) -> Result<TrainOutcome> {
        for _ in 0..self.job.cfg.steps {
            let loss = self.train_step()?;
            if verbose && self.job.step % self.job.cfg.eval_every.max(1) == 0 {
                println!(
                    "step {:>5}  loss {:.4}  ppl {:.2}  lr {:.5}  tok/s {:.0}",
                    self.job.step,
                    loss,
                    loss.exp(),
                    self.job.schedule.lr(self.job.step.saturating_sub(1)),
                    self.job.throughput.tokens_per_sec()
                );
            }
        }
        let valid_loss = self.eval_loss(loader, 8)?;
        let final_loss = self.job.curve.tail_mean_loss(10).unwrap_or(f32::NAN);
        Ok(TrainOutcome {
            label: self.job.curve.label.clone(),
            final_loss,
            final_ppl: final_loss.exp(),
            valid_loss,
            valid_ppl: valid_loss.exp(),
            tokens_per_sec: self.job.throughput.tokens_per_sec(),
            state_bytes: self.optimizer_state_bytes(),
            curve: self.job.curve.clone(),
        })
    }

    /// Outcome summary for the steps run so far (used by benches that
    /// drive `train_step` manually for mid-run checkpoints).
    pub fn run_summary(&self, loader: &DataLoader) -> TrainOutcome {
        let valid_loss = self.eval_loss(loader, 8).unwrap_or(f32::NAN);
        let final_loss = self.job.curve.tail_mean_loss(10).unwrap_or(f32::NAN);
        TrainOutcome {
            label: self.job.curve.label.clone(),
            final_loss,
            final_ppl: final_loss.exp(),
            valid_loss,
            valid_ppl: valid_loss.exp(),
            tokens_per_sec: self.job.throughput.tokens_per_sec(),
            state_bytes: self.optimizer_state_bytes(),
            curve: self.job.curve.clone(),
        }
    }

    /// Params-only checkpoint (eval workflows). The full-state
    /// suspend/resume path is `JobState::snapshot`/`restore`.
    pub fn save_checkpoint(&self, path: &str) -> Result<()> {
        let mut ck = crate::checkpoint::Checkpoint::new(self.job.step as u64);
        for (s, p) in self.job.shapes.iter().zip(&self.job.params) {
            ck.insert(&s.name, p.clone());
        }
        ck.save(path)
    }

    pub fn load_checkpoint(&mut self, path: &str) -> Result<()> {
        let ck = crate::checkpoint::Checkpoint::load(path)?;
        for (s, p) in self.job.shapes.iter().zip(self.job.params.iter_mut()) {
            let t = ck
                .tensors
                .get(&s.name)
                .ok_or_else(|| anyhow::anyhow!("checkpoint missing {}", s.name))?;
            anyhow::ensure!(t.shape() == s.shape, "shape mismatch for {}", s.name);
            *p = t.clone();
        }
        self.job.step = ck.step as usize;
        Ok(())
    }
}

/// Parameter init mirroring `model.init_params`: matrices He-scaled
/// normal, 1D bias-like (name ends in 'b') zeros, other 1D ones.
pub fn init_param(name: &str, shape: &[usize], rng: &mut crate::rng::Rng) -> Tensor {
    if shape.len() == 1 {
        if name.ends_with('b') {
            Tensor::zeros(shape)
        } else {
            Tensor::full(shape, 1.0)
        }
    } else {
        Tensor::he_init(shape, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_param_kinds() {
        let mut rng = crate::rng::Rng::new(0);
        assert_eq!(init_param("norm1", &[4], &mut rng).data(), &[1.0; 4]);
        assert_eq!(init_param("norm1b", &[4], &mut rng).data(), &[0.0; 4]);
        let w = init_param("attn.wq", &[8, 8], &mut rng);
        assert!(w.frob_norm() > 0.0);
    }
}
