//! Metrics: loss-curve recording, perplexity, throughput meters, and
//! CSV emission for the figure benches.
//!
//! All CSV serialization flows through `obs::sink::csv_table` (format
//! strings — the byte-compatibility contract — stay here), and all
//! wall-time reads flow through `obs::clock` so nothing in this module
//! ever touches the non-monotonic system clock.

use crate::obs::clock::Stopwatch;
use crate::obs::sink::csv_table;

/// One recorded training point.
#[derive(Clone, Copy, Debug)]
pub struct Point {
    pub step: usize,
    pub loss: f32,
    pub tokens_seen: usize,
    pub wall_secs: f64,
}

#[derive(Clone, Debug, Default)]
pub struct LossCurve {
    pub label: String,
    pub points: Vec<Point>,
}

impl LossCurve {
    pub fn new(label: &str) -> Self {
        LossCurve { label: label.into(), points: Vec::new() }
    }

    pub fn push(&mut self, step: usize, loss: f32, tokens_seen: usize, wall_secs: f64) {
        self.points.push(Point { step, loss, tokens_seen, wall_secs });
    }

    pub fn final_loss(&self) -> Option<f32> {
        self.points.last().map(|p| p.loss)
    }

    pub fn final_ppl(&self) -> Option<f32> {
        self.final_loss().map(ppl)
    }

    /// Mean loss over the last `k` points (smoother than the last
    /// single batch).
    pub fn tail_mean_loss(&self, k: usize) -> Option<f32> {
        if self.points.is_empty() {
            return None;
        }
        let tail = &self.points[self.points.len().saturating_sub(k)..];
        Some(tail.iter().map(|p| p.loss).sum::<f32>() / tail.len() as f32)
    }

    /// Largest single-step loss increase — the "spike" statistic used
    /// by the Fig 3 NL-ablation bench.
    pub fn max_spike(&self) -> f32 {
        self.points
            .windows(2)
            .map(|w| w[1].loss - w[0].loss)
            .fold(0.0f32, f32::max)
    }

    /// First step whose loss drops below `threshold` (convergence
    /// speed comparison, Fig 4).
    pub fn first_step_below(&self, threshold: f32) -> Option<usize> {
        self.points.iter().find(|p| p.loss < threshold).map(|p| p.step)
    }

    pub fn to_csv(&self) -> String {
        csv_table(
            &["step", "loss", "ppl", "tokens_seen", "wall_secs"],
            self.points.iter().map(|p| {
                vec![
                    p.step.to_string(),
                    format!("{:.6}", p.loss),
                    format!("{:.4}", ppl(p.loss)),
                    p.tokens_seen.to_string(),
                    format!("{:.3}", p.wall_secs),
                ]
            }),
        )
    }
}

pub fn ppl(loss: f32) -> f32 {
    loss.exp()
}

/// Tokens/sec meter on the monotonic, resumable `obs::clock`
/// stopwatch: a suspended job checkpoints `elapsed_secs()` and
/// restores with [`Throughput::resume`], so wall times never restart
/// at zero (or step backwards) across suspend/resume cycles.
pub struct Throughput {
    watch: Stopwatch,
    tokens: usize,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Throughput { watch: Stopwatch::start(), tokens: 0 }
    }

    /// Rebuild a meter from checkpointed state: `elapsed_secs` seconds
    /// and `tokens` tokens already on the clock.
    pub fn resume(elapsed_secs: f64, tokens: usize) -> Self {
        Throughput { watch: Stopwatch::resume(elapsed_secs), tokens }
    }

    pub fn add_tokens(&mut self, n: usize) {
        self.tokens += n;
    }

    pub fn tokens_per_sec(&self) -> f64 {
        let secs = self.watch.elapsed_secs();
        if secs <= 0.0 {
            return 0.0;
        }
        self.tokens as f64 / secs
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.watch.elapsed_secs()
    }
}

/// One adaptive-compression event: a cadence boundary where the
/// adapt subsystem probed and (possibly) re-selected decompositions.
/// Emitted by `adapt::AdaptController::post_step`.
#[derive(Clone, Debug)]
pub struct AdaptEvent {
    pub step: usize,
    /// Migrations applied at this event (resets included).
    pub migrations: usize,
    /// How many of those took the reset fallback.
    pub resets: usize,
    /// Measured bank state bytes *after* the event — the live half of
    /// the accountant's worst-case-vs-live story.
    pub state_bytes: usize,
    /// Adaptive parameters per held (basis, level), as sorted
    /// `("haar-2", count)` pairs.
    pub histogram: Vec<(String, usize)>,
}

impl AdaptEvent {
    /// Compact `haar-2:5|db4-3:2` spelling for logs and CSV cells.
    pub fn histogram_label(&self) -> String {
        self.histogram
            .iter()
            .map(|(k, c)| format!("{k}:{c}"))
            .collect::<Vec<_>>()
            .join("|")
    }
}

/// Per-run record of adaptive-compression events (state bytes over
/// time, selection histograms) — the fig10 bench's raw material,
/// written next to the loss curve by the trainer CLI.
#[derive(Clone, Debug, Default)]
pub struct AdaptTrace {
    pub label: String,
    pub events: Vec<AdaptEvent>,
}

impl AdaptTrace {
    pub fn new(label: &str) -> Self {
        AdaptTrace { label: label.into(), events: Vec::new() }
    }

    pub fn push(&mut self, e: AdaptEvent) {
        self.events.push(e);
    }

    pub fn total_migrations(&self) -> usize {
        self.events.iter().map(|e| e.migrations).sum()
    }

    pub fn total_resets(&self) -> usize {
        self.events.iter().map(|e| e.resets).sum()
    }

    /// Peak live state bytes across events (budget-compliance check).
    pub fn max_state_bytes(&self) -> usize {
        self.events.iter().map(|e| e.state_bytes).max().unwrap_or(0)
    }

    pub fn final_histogram(&self) -> Option<&[(String, usize)]> {
        self.events.last().map(|e| e.histogram.as_slice())
    }

    pub fn to_csv(&self) -> String {
        csv_table(
            &["step", "migrations", "resets", "state_bytes", "histogram"],
            self.events.iter().map(|e| {
                vec![
                    e.step.to_string(),
                    e.migrations.to_string(),
                    e.resets.to_string(),
                    e.state_bytes.to_string(),
                    e.histogram_label(),
                ]
            }),
        )
    }
}

/// One optimizer step's cross-replica communication cost, recorded by
/// `ddp::GradReducer`. `full_bytes` is what a naive full-gradient
/// all-reduce would have moved for the same step; `bytes` is what the
/// (possibly approximation-band-compressed) reduction actually moved.
/// Both count payload bytes per tree edge: `(R-1) · elems · 4` summed
/// over parameters and microbatches.
#[derive(Clone, Copy, Debug)]
pub struct CommRecord {
    pub step: usize,
    pub full_bytes: usize,
    pub bytes: usize,
}

/// Per-run record of cross-replica communication volume — the
/// measured half of the GWT paper's "compressed communication" story
/// (a `gwt-2` run moves ~2² times fewer bytes than full-band; see
/// docs/ddp.md for the exact accounting).
#[derive(Clone, Debug, Default)]
pub struct CommLog {
    pub records: Vec<CommRecord>,
}

impl CommLog {
    pub fn push(&mut self, r: CommRecord) {
        self.records.push(r);
    }

    pub fn total_bytes(&self) -> usize {
        self.records.iter().map(|r| r.bytes).sum()
    }

    pub fn total_full_bytes(&self) -> usize {
        self.records.iter().map(|r| r.full_bytes).sum()
    }

    /// Full-band bytes per actually-moved byte (≥ 1 when compression
    /// is active, 1.0 when reducing full-band, `None` with no traffic).
    pub fn compression_ratio(&self) -> Option<f64> {
        let moved = self.total_bytes();
        if moved == 0 {
            return None;
        }
        Some(self.total_full_bytes() as f64 / moved as f64)
    }

    pub fn to_csv(&self) -> String {
        csv_table(
            &["step", "full_bytes", "bytes"],
            self.records.iter().map(|r| {
                vec![
                    r.step.to_string(),
                    r.full_bytes.to_string(),
                    r.bytes.to_string(),
                ]
            }),
        )
    }
}

/// Write a set of curves as one CSV per curve under `dir`.
pub fn write_curves(dir: &str, curves: &[LossCurve]) -> anyhow::Result<()> {
    for c in curves {
        let safe: String = c
            .label
            .chars()
            .map(|ch| if ch.is_alphanumeric() { ch } else { '_' })
            .collect();
        crate::obs::sink::write_csv_file(&format!("{dir}/{safe}.csv"), &c.to_csv())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(losses: &[f32]) -> LossCurve {
        let mut c = LossCurve::new("t");
        for (i, &l) in losses.iter().enumerate() {
            c.push(i, l, i * 100, i as f64);
        }
        c
    }

    #[test]
    fn ppl_is_exp() {
        assert!((ppl(0.0) - 1.0).abs() < 1e-6);
        assert!((ppl(2.0) - 2f32.exp()).abs() < 1e-4);
    }

    #[test]
    fn tail_mean_and_final() {
        let c = curve(&[5.0, 4.0, 3.0, 2.0]);
        assert_eq!(c.final_loss(), Some(2.0));
        assert!((c.tail_mean_loss(2).unwrap() - 2.5).abs() < 1e-6);
        assert!((c.tail_mean_loss(100).unwrap() - 3.5).abs() < 1e-6);
        assert!(curve(&[]).tail_mean_loss(3).is_none());
    }

    #[test]
    fn spike_detection() {
        let c = curve(&[5.0, 3.0, 4.5, 2.0]);
        assert!((c.max_spike() - 1.5).abs() < 1e-6);
        let mono = curve(&[3.0, 2.0, 1.0]);
        assert_eq!(mono.max_spike(), 0.0);
    }

    #[test]
    fn convergence_step() {
        let c = curve(&[5.0, 3.0, 2.5, 1.0]);
        assert_eq!(c.first_step_below(2.6), Some(2));
        assert_eq!(c.first_step_below(0.5), None);
    }

    #[test]
    fn csv_format() {
        let c = curve(&[1.0]);
        let csv = c.to_csv();
        assert!(csv.starts_with("step,loss"));
        assert!(csv.lines().count() == 2);
    }

    #[test]
    fn adapt_trace_totals_and_csv() {
        let mut t = AdaptTrace::new("adapt");
        assert_eq!(t.max_state_bytes(), 0);
        assert!(t.final_histogram().is_none());
        t.push(AdaptEvent {
            step: 10,
            migrations: 3,
            resets: 1,
            state_bytes: 4096,
            histogram: vec![("haar-2".into(), 2), ("haar-3".into(), 1)],
        });
        t.push(AdaptEvent {
            step: 20,
            migrations: 0,
            resets: 0,
            state_bytes: 2048,
            histogram: vec![("haar-3".into(), 3)],
        });
        assert_eq!(t.total_migrations(), 3);
        assert_eq!(t.total_resets(), 1);
        assert_eq!(t.max_state_bytes(), 4096);
        assert_eq!(t.final_histogram().unwrap(), &[("haar-3".to_string(), 3)]);
        let csv = t.to_csv();
        assert!(csv.starts_with("step,migrations"));
        assert!(csv.contains("10,3,1,4096,haar-2:2|haar-3:1"));
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn comm_log_totals_ratio_and_csv() {
        let mut log = CommLog::default();
        assert_eq!(log.total_bytes(), 0);
        assert!(log.compression_ratio().is_none());
        log.push(CommRecord { step: 1, full_bytes: 4096, bytes: 1024 });
        log.push(CommRecord { step: 2, full_bytes: 4096, bytes: 1024 });
        assert_eq!(log.total_bytes(), 2048);
        assert_eq!(log.total_full_bytes(), 8192);
        assert!((log.compression_ratio().unwrap() - 4.0).abs() < 1e-12);
        let csv = log.to_csv();
        assert!(csv.starts_with("step,full_bytes,bytes"));
        assert!(csv.contains("1,4096,1024"));
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn comm_log_zero_traffic_edge_cases() {
        // Records can exist with zero moved bytes (degenerate ledger
        // input): the ratio must stay `None`, never a division by
        // zero or an inf, and totals must be plain zeros.
        let mut log = CommLog::default();
        log.push(CommRecord { step: 1, full_bytes: 0, bytes: 0 });
        log.push(CommRecord { step: 2, full_bytes: 0, bytes: 0 });
        assert_eq!(log.total_bytes(), 0);
        assert_eq!(log.total_full_bytes(), 0);
        assert!(log.compression_ratio().is_none());
        // A full-band-only log reads ratio 1.0 exactly.
        log.push(CommRecord { step: 3, full_bytes: 64, bytes: 64 });
        assert_eq!(log.compression_ratio().unwrap(), 1.0);
        // CSV stays well-formed with zero rows present.
        assert!(log.to_csv().contains("1,0,0"));
    }

    #[test]
    fn throughput_counts() {
        let mut t = Throughput::new();
        t.add_tokens(500);
        t.add_tokens(500);
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.tokens_per_sec() > 0.0);
    }

    #[test]
    fn throughput_elapsed_is_monotone_and_resumable() {
        let t = Throughput::new();
        let mut last = 0.0;
        for _ in 0..10 {
            let e = t.elapsed_secs();
            assert!(e >= 0.0);
            assert!(e >= last);
            last = e;
        }
        let r = Throughput::resume(last + 50.0, 1000);
        assert!(r.elapsed_secs() >= last + 50.0, "resume keeps the base");
        assert!(r.tokens_per_sec() > 0.0);
    }
}
