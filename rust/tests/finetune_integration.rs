//! Fine-tuning integration: classification artifacts + FineTuner.

use std::sync::Arc;

use gwt::config::{OptSpec, TrainConfig};
use gwt::eval::tasks::{ClsTask, TaskSpec};
use gwt::eval::FineTuner;
use gwt::runtime::Runtime;

fn runtime() -> Option<Arc<Runtime>> {
    match Runtime::load("artifacts") {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

fn ft_cfg(opt: OptSpec) -> TrainConfig {
    TrainConfig {
        preset: "ft-micro".into(),
        optimizer: opt,
        lr: 0.0005,
        alpha: 1.0,
        ..Default::default()
    }
}

fn easy_task(classes: usize, seed: u64) -> ClsTask {
    ClsTask::generate(TaskSpec {
        name: "it".into(),
        classes,
        marker_rate: 0.25,
        seq_len: 64,
        train_examples: 96,
        test_examples: 48,
        seed,
    })
}

#[test]
fn gwt_finetune_beats_chance() {
    let Some(rt) = runtime() else { return };
    let task = easy_task(4, 11);
    let mut ft =
        FineTuner::new(rt, ft_cfg(OptSpec::gwt(2)), 4, None).unwrap();
    let out = ft.run(&task, 3).unwrap();
    assert!(
        out.accuracy > 0.45,
        "gwt fine-tune acc {} barely above chance 0.25",
        out.accuracy
    );
}

#[test]
fn adam_finetune_beats_chance_binary() {
    let Some(rt) = runtime() else { return };
    let task = easy_task(2, 12);
    let mut ft = FineTuner::new(rt, ft_cfg(OptSpec::adam()), 2, None).unwrap();
    let out = ft.run(&task, 2).unwrap();
    assert!(out.accuracy > 0.7, "adam acc {}", out.accuracy);
}

#[test]
fn zero_head_starts_at_chance() {
    let Some(rt) = runtime() else { return };
    let task = easy_task(4, 13);
    let ft = FineTuner::new(rt, ft_cfg(OptSpec::adam()), 4, None).unwrap();
    let acc = ft.accuracy(&task).unwrap();
    // Untrained zero head: argmax is constant => accuracy ~ class
    // prior of one label (chance-ish).
    assert!(acc < 0.5, "untrained acc suspiciously high: {acc}");
}

#[test]
fn lora_and_galore_paths_run() {
    let Some(rt) = runtime() else { return };
    let task = easy_task(3, 14);
    for opt in [
        OptSpec::Lora { rank_denom: 64 },
        OptSpec::galore(64),
    ] {
        let mut ft = FineTuner::new(rt.clone(), ft_cfg(opt), 3, None).unwrap();
        let out = ft.run(&task, 1).unwrap();
        assert!(out.final_loss.is_finite(), "{opt:?}");
        assert!(out.accuracy >= 0.15, "{opt:?} acc {}", out.accuracy);
    }
}
