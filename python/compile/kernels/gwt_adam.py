"""L1 Pallas kernel: fused GWT-Adam state update (paper Algorithm 1).

One ``pallas_call`` performs, per row tile, entirely in VMEM:

    1. multi-level Haar forward transform of the gradient block,
    2. Adam first/second-moment update on the approximation band only,
    3. normalization of approximation + detail bands by sqrt(V)+eps
       (denominator nearest-upsampled per detail band),
    4. multi-level inverse transform back to the weight space.

This is the paper's hot spot.  The GPU implementation (ptwt + torch
Adam) makes >= 2l+3 HBM round trips per step; this kernel makes one
read of (g, m, v) and one write of (update, m', v').

The moment tensors are 2^level smaller than the gradient, so the m/v
BlockSpecs index a narrower array with the same row tiling.

Bias correction, lr, alpha, and the weight subtraction are applied by
the caller (L2 ``opt_steps.py`` / rust) — they are cheap elementwise
epilogues XLA fuses anyway, and keeping them out makes the kernel
stateless with respect to the step counter.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .haar import haar_fwd_block, haar_inv_block, pick_tile_m


def _gwt_adam_kernel(
    g_ref,
    m_ref,
    v_ref,
    upd_ref,
    m_out_ref,
    v_out_ref,
    *,
    level: int,
    beta1: float,
    beta2: float,
    eps: float,
):
    g = g_ref[...]
    n = g.shape[-1]
    q = n >> level

    coeffs = haar_fwd_block(g, level)
    a = coeffs[..., :q]

    m_new = beta1 * m_ref[...] + (1.0 - beta1) * a
    v_new = beta2 * v_ref[...] + (1.0 - beta2) * a * a
    denom = jnp.sqrt(v_new) + eps

    parts = [m_new / denom]
    off = q
    for k in range(level, 0, -1):
        w = n >> k
        d = coeffs[..., off : off + w]
        off += w
        rep = 1 << (level - k)
        dd = jnp.repeat(denom, rep, axis=-1) if rep > 1 else denom
        parts.append(d / dd)

    upd_ref[...] = haar_inv_block(jnp.concatenate(parts, axis=-1), level)
    m_out_ref[...] = m_new
    v_out_ref[...] = v_new


@functools.partial(
    jax.jit, static_argnames=("level", "beta1", "beta2", "eps")
)
def gwt_adam_pallas(
    g: jnp.ndarray,
    m: jnp.ndarray,
    v: jnp.ndarray,
    *,
    level: int,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-6,
):
    """Fused GWT-Adam update. Returns (update, m_new, v_new).

    Shapes: g (M, N); m, v (M, N / 2**level). N % 2**level == 0.
    Matches ``ref.gwt_normalized_update`` elementwise.
    """
    mm, n = g.shape
    q = n >> level
    if level == 0:
        raise ValueError("level must be >= 1 for the fused kernel")
    if n % (1 << level) != 0:
        raise ValueError(f"width {n} not divisible by 2^{level}")
    if m.shape != (mm, q) or v.shape != (mm, q):
        raise ValueError(f"moment shapes {m.shape}/{v.shape} != {(mm, q)}")
    # 6 live operands of the full width bound the VMEM footprint.
    tm = pick_tile_m(mm, n, operands=6)
    kernel = functools.partial(
        _gwt_adam_kernel, level=level, beta1=beta1, beta2=beta2, eps=eps
    )
    return pl.pallas_call(
        kernel,
        grid=(mm // tm,),
        in_specs=[
            pl.BlockSpec((tm, n), lambda i: (i, 0)),
            pl.BlockSpec((tm, q), lambda i: (i, 0)),
            pl.BlockSpec((tm, q), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tm, n), lambda i: (i, 0)),
            pl.BlockSpec((tm, q), lambda i: (i, 0)),
            pl.BlockSpec((tm, q), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mm, n), g.dtype),
            jax.ShapeDtypeStruct((mm, q), g.dtype),
            jax.ShapeDtypeStruct((mm, q), g.dtype),
        ],
        interpret=True,
    )(g, m, v)
