"""HLO cost-analysis tool: parser unit tests + artifact invariants."""

import json
import os

import pytest

from compile.hlo_cost import parse_hlo

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


def test_parse_counts_dots_and_flops():
    text = """
HloModule m
ENTRY %main (a: f32[4,8], b: f32[8,16]) -> f32[4,16] {
  %a = f32[4,8] parameter(0)
  %b = f32[8,16] parameter(1)
  ROOT %dot = f32[4,16] dot(f32[4,8] %a, f32[8,16] %b)
}
"""
    r = parse_hlo(text)
    assert r["dot_count"] == 1
    # 2 * 4*16 * 8 = 1024 FLOPs.
    assert abs(r["dot_gflops"] - 1024 / 1e9) < 1e-12


def test_parse_elementwise():
    text = "  %x = f32[10,10] add(f32[10,10] %a, f32[10,10] %b)\n"
    r = parse_hlo(text)
    assert r["op_histogram"].get("add") == 1
    assert abs(r["elementwise_melems"] - 100 / 1e6) < 1e-12


@needs_artifacts
def test_train_step_has_matmuls_and_no_recompute_blowup():
    man = json.load(open(MANIFEST))
    tr = parse_hlo(
        open(os.path.join(ART, man["artifacts"]["train_step_nano"]["file"])).read()
    )
    ev = parse_hlo(
        open(os.path.join(ART, man["artifacts"]["eval_loss_nano"]["file"])).read()
    )
    assert tr["dot_count"] > ev["dot_count"] > 0
    # Backward pass roughly doubles dot work; >3.5x means accidental
    # recomputation snuck into the lowering.
    ratio = tr["dot_gflops"] / ev["dot_gflops"]
    assert 1.5 < ratio <= 3.5, f"train/eval dot ratio {ratio}"


@needs_artifacts
def test_gwt_adam_artifact_is_matmul_free():
    # The wavelet path must lower to reshapes/elementwise only — the
    # paper's complexity claim (O(mn) vs GaLore's O(mn^2)) depends on
    # there being no dot in the optimizer step.
    man = json.load(open(MANIFEST))
    r = parse_hlo(
        open(
            os.path.join(ART, man["artifacts"]["gwt_adam_l2_64x64"]["file"])
        ).read()
    )
    assert r["dot_count"] == 0, r["op_histogram"]
