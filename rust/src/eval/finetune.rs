//! Fine-tuning loop over the classification artifacts
//! (`cls_train_step_<preset>_k<K>` / `cls_logits_<preset>_k<K>`).
//!
//! Mirrors the paper's fine-tuning protocol (§IV-B): the selected
//! memory-efficient method is applied to *all* linear layers (not
//! just attention/MLP), a fixed small number of epochs, accuracy on a
//! held-out test split, best-of over a small lr sweep.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::{presets, TrainConfig};
use crate::coordinator::trainer::init_param;
use crate::coordinator::CosineSchedule;
use crate::memory::ParamShape;
use crate::optim::{build_optimizers_sharded, step_bank, ParamOptimizer};
use crate::pool::Sharding;
use crate::runtime::{
    literal_f32, literal_labels, literal_tokens, scalar_from_literal, Runtime,
};
use crate::tensor::Tensor;

use super::tasks::ClsTask;

pub struct FineTuner {
    runtime: Arc<Runtime>,
    cfg: TrainConfig,
    preset: &'static presets::ModelPreset,
    shapes: Vec<ParamShape>, // backbone + zcls.head (sorted order)
    params: Vec<Tensor>,
    bank: Vec<ParamOptimizer>,
    classes: usize,
    /// Step-engine dispatcher (one persistent pool per fine-tuning
    /// run, resolved once from `cfg.threads`).
    sharding: Sharding,
}

#[derive(Clone, Debug)]
pub struct FtOutcome {
    pub task: String,
    pub method: String,
    pub accuracy: f64,
    pub final_loss: f32,
    pub state_bytes: usize,
}

impl FineTuner {
    /// `backbone`: optional pretrained weights (name -> tensor); falls
    /// back to fresh init (fine for the synthetic suites — both
    /// regimes are compared under identical backbones).
    pub fn new(
        runtime: Arc<Runtime>,
        mut cfg: TrainConfig,
        classes: usize,
        backbone: Option<&std::collections::BTreeMap<String, Tensor>>,
    ) -> Result<FineTuner> {
        let preset = presets::find(&cfg.preset)?;
        // Fine-tuning applies the method to ALL linear layers: mark
        // every 2D parameter eligible (paper §IV-B "all linear
        // layers"), except embeddings which stay on Adam.
        let mut shapes = preset.param_shapes();
        for s in &mut shapes {
            if s.shape.len() == 2 && !s.name.contains("emb") && !s.name.contains("head")
            {
                s.eligible = true;
            }
        }
        // Classification head participates as a plain Adam param.
        shapes.push(ParamShape {
            name: "zcls.head".into(),
            shape: vec![preset.d_model, classes],
            eligible: false,
        });
        shapes.sort_by(|a, b| a.name.cmp(&b.name));

        let mut rng = crate::rng::Rng::new(cfg.seed);
        let params: Vec<Tensor> = shapes
            .iter()
            .map(|s| {
                if s.name == "zcls.head" {
                    // Zero head: uniform logits at start.
                    return Tensor::zeros(&s.shape);
                }
                if let Some(bb) = backbone {
                    if let Some(t) = bb.get(&s.name) {
                        return t.clone();
                    }
                }
                init_param(&s.name, &s.shape, &mut rng)
            })
            .collect();
        // Fine-tuning disables the NL limiter (paper uses it for
        // pretraining stability only).
        cfg.nl_gamma = 0.0;
        // One pool per fine-tuning run, shared with the bank (row
        // sharding would use it if the bank were single-param).
        let sharding = Sharding::pool(cfg.resolve_threads());
        let bank = build_optimizers_sharded(
            &shapes,
            &cfg,
            Some(runtime.clone()),
            sharding.clone(),
        )?;
        Ok(FineTuner {
            runtime,
            cfg,
            preset,
            shapes,
            params,
            bank,
            classes,
            sharding,
        })
    }

    fn run_batch(
        &mut self,
        tokens: &[i32],
        labels: &[i32],
        lr_t: f32,
    ) -> Result<f32> {
        let key = format!(
            "cls_train_step_{}_k{}",
            self.cfg.preset, self.classes
        );
        let exec = self.runtime.exec(&key).with_context(|| {
            format!("fine-tune artifact for k={} missing", self.classes)
        })?;
        let mut inputs = Vec::with_capacity(self.params.len() + 2);
        for p in &self.params {
            inputs.push(literal_f32(p)?);
        }
        inputs.push(literal_tokens(
            tokens,
            self.preset.batch,
            self.preset.seq_len,
        )?);
        inputs.push(literal_labels(labels)?);
        let outs = exec.run(&inputs)?;
        let loss = scalar_from_literal(&outs[0])?;
        let grads = self
            .shapes
            .iter()
            .enumerate()
            .map(|(i, s)| {
                Ok(Tensor::new(&s.shape, outs[1 + i].to_vec::<f32>()?))
            })
            .collect::<Result<Vec<_>>>()?;
        step_bank(&mut self.bank, &mut self.params, &grads, lr_t, &self.sharding);
        Ok(loss)
    }

    /// Fine-tune on `task.train` for `epochs`, return test accuracy.
    pub fn run(&mut self, task: &ClsTask, epochs: usize) -> Result<FtOutcome> {
        let bs = self.preset.batch;
        anyhow::ensure!(
            task.spec.seq_len == self.preset.seq_len,
            "task seq_len {} != preset {}",
            task.spec.seq_len,
            self.preset.seq_len
        );
        let steps_per_epoch = task.train.len() / bs;
        let schedule = CosineSchedule::new(
            self.cfg.lr,
            epochs * steps_per_epoch,
            self.cfg.warmup_frac,
        );
        let mut step = 0;
        let mut last_loss = f32::NAN;
        for _ in 0..epochs {
            for chunk in task.train.chunks_exact(bs) {
                let mut tokens = Vec::with_capacity(bs * self.preset.seq_len);
                let mut labels = Vec::with_capacity(bs);
                for ex in chunk {
                    tokens.extend_from_slice(&ex.tokens);
                    labels.push(ex.label);
                }
                last_loss =
                    self.run_batch(&tokens, &labels, schedule.lr(step))?;
                step += 1;
            }
        }
        let accuracy = self.accuracy(task)?;
        Ok(FtOutcome {
            task: task.spec.name.clone(),
            method: self.cfg.optimizer.label(),
            accuracy,
            final_loss: last_loss,
            state_bytes: self
                .bank
                .iter()
                .map(|b| b.state_bytes())
                .sum(),
        })
    }

    /// Argmax accuracy on the test split via `cls_logits`.
    pub fn accuracy(&self, task: &ClsTask) -> Result<f64> {
        let key = format!("cls_logits_{}_k{}", self.cfg.preset, self.classes);
        let exec = self.runtime.exec(&key)?;
        let bs = self.preset.batch;
        let mut correct = 0usize;
        let mut total = 0usize;
        for chunk in task.test.chunks_exact(bs) {
            let mut tokens = Vec::with_capacity(bs * self.preset.seq_len);
            for ex in chunk {
                tokens.extend_from_slice(&ex.tokens);
            }
            let mut inputs = Vec::with_capacity(self.params.len() + 1);
            for p in &self.params {
                inputs.push(literal_f32(p)?);
            }
            inputs.push(literal_tokens(
                &tokens,
                self.preset.batch,
                self.preset.seq_len,
            )?);
            let outs = exec.run(&inputs)?;
            let logits = outs[0].to_vec::<f32>()?;
            for (bi, ex) in chunk.iter().enumerate() {
                let row = &logits[bi * self.classes..(bi + 1) * self.classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0 as i32;
                correct += (pred == ex.label) as usize;
                total += 1;
            }
        }
        anyhow::ensure!(total > 0, "no test examples consumed");
        Ok(correct as f64 / total as f64)
    }
}
