"""L2 model: shapes, losses, gradient plumbing, preset consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def toy_cfg(arch="llama", **kw):
    base = dict(
        name="t", arch=arch, vocab=64, d_model=32, n_layers=2,
        n_heads=4, d_ff=48, seq_len=16, batch=2,
    )
    base.update(kw)
    return M.ModelConfig(**base)


def tokens_for(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.integers(2, cfg.vocab, size=(cfg.batch, cfg.seq_len)),
        dtype=jnp.int32,
    )


@pytest.mark.parametrize("arch", ["llama", "gpt", "qwen", "bert"])
def test_forward_shapes(arch):
    cfg = toy_cfg(arch)
    p = M.init_params(cfg)
    logits = M.forward(cfg, p, tokens_for(cfg))
    assert logits.shape == (cfg.batch, cfg.seq_len, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["llama", "gpt", "qwen", "bert"])
def test_loss_finite_and_near_uniform_at_init(arch):
    cfg = toy_cfg(arch)
    p = M.init_params(cfg)
    loss = M.lm_loss(cfg, p, tokens_for(cfg))
    assert bool(jnp.isfinite(loss))
    # Random init ≈ uniform prediction => loss ≈ log(vocab).
    assert abs(float(loss) - np.log(cfg.vocab)) < 2.0


@pytest.mark.parametrize("arch", ["llama", "gpt", "qwen", "bert"])
def test_train_step_outputs_match_specs(arch):
    cfg = toy_cfg(arch)
    specs = M.param_specs(cfg)
    p = M.init_params(cfg)
    out = M.make_train_step(cfg)(*M.pack(cfg, p), tokens_for(cfg))
    assert len(out) == 1 + len(specs)
    assert out[0].shape == ()
    for g, s in zip(out[1:], specs):
        assert g.shape == s.shape, s.name
        assert bool(jnp.all(jnp.isfinite(g))), s.name


def test_param_specs_sorted_and_unique():
    for name, cfg in M.PRESETS.items():
        specs = M.param_specs(cfg)
        names = [s.name for s in specs]
        assert names == sorted(names), name
        assert len(set(names)) == len(names), name


def test_gwt_eligible_are_2d_attention_mlp():
    cfg = M.PRESETS["nano"]
    for s in M.param_specs(cfg):
        if s.gwt:
            assert len(s.shape) == 2
            assert ".attn." in s.name or ".mlp." in s.name
        else:
            assert ".attn." not in s.name and ".mlp." not in s.name


def test_tied_qwen_has_no_lm_head():
    names = [s.name for s in M.param_specs(M.PRESETS["qwen-nano"])]
    assert "lm_head" not in names
    assert "tok_emb" in names


def test_training_reduces_loss_sgd():
    # Ten SGD steps on a repeated batch must reduce the loss: checks
    # that gradients actually point downhill through the whole model.
    cfg = toy_cfg("llama")
    p = M.init_params(cfg, seed=1)
    tok = tokens_for(cfg, seed=2)
    step = jax.jit(M.make_train_step(cfg))
    specs = M.param_specs(cfg)
    first = None
    flat = list(M.pack(cfg, p))
    for _ in range(10):
        out = step(*flat, tok)
        loss, grads = out[0], out[1:]
        if first is None:
            first = float(loss)
        flat = [w - 0.5 * g for w, g in zip(flat, grads)]
    last = float(M.lm_loss(cfg, {s.name: t for s, t in zip(specs, flat)}, tok))
    assert last < first - 0.1, (first, last)


def test_bert_mask_positions_only():
    # Loss must not depend on tokens at unmasked positions' *targets* —
    # masked-LM scores only every BERT_MASK_STRIDE-th position.
    cfg = toy_cfg("bert", seq_len=14)
    p = M.init_params(cfg)
    tok = tokens_for(cfg, seed=3)
    base = float(M.lm_loss(cfg, p, tok))
    assert np.isfinite(base)


def test_cls_head_shapes_and_loss():
    cfg = toy_cfg("llama")
    k = 4
    p = M.init_params(cfg)
    p["zcls.head"] = jnp.zeros((cfg.d_model, k))
    tok = tokens_for(cfg)
    logits = M.cls_logits(cfg, p, tok, k)
    assert logits.shape == (cfg.batch, k)
    labels = jnp.asarray([1, 3], dtype=jnp.int32)
    loss = M.cls_loss(cfg, p, tok, labels, k)
    # Zero head => uniform logits => loss == log(k).
    np.testing.assert_allclose(float(loss), np.log(k), rtol=1e-5)


def test_cls_train_step_grad_count():
    cfg = toy_cfg("llama")
    k = 3
    specs = M.cls_param_specs(cfg, k)
    p = M.init_params(cfg)
    p["zcls.head"] = jnp.full((cfg.d_model, k), 0.01)
    flat = tuple(p[s.name] for s in specs)
    labels = jnp.asarray([0, 2], dtype=jnp.int32)
    out = M.make_cls_train_step(cfg, k)(*flat, tokens_for(cfg), labels)
    assert len(out) == 1 + len(specs)


def test_presets_dims_divisible_for_aot_levels():
    # Every GWT-eligible shape must support levels 1..3 (AOT set).
    from compile.aot import AOT_LEVELS, gwt_shapes

    for name, cfg in M.PRESETS.items():
        for (m, n) in gwt_shapes(cfg):
            for level in AOT_LEVELS:
                assert n % (1 << level) == 0, (name, m, n, level)


def test_rope_preserves_norm():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 4, 8, 16)),
                    dtype=jnp.float32)
    y = M.rope(x)
    np.testing.assert_allclose(
        jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1), rtol=1e-5
    )


def test_rms_vs_layer_norm_basic():
    x = jnp.asarray([[1.0, -1.0, 2.0, -2.0]])
    w = jnp.ones(4)
    b = jnp.zeros(4)
    ln = M.layer_norm(x, w, b)
    np.testing.assert_allclose(float(jnp.mean(ln)), 0.0, atol=1e-6)
    rn = M.rms_norm(x, w)
    np.testing.assert_allclose(
        float(jnp.sqrt(jnp.mean(rn * rn))), 1.0, rtol=1e-4
    )
