//! Measured-vs-analytic memory parity: for every optimizer
//! composition, the accountant's implementation-unit prediction
//! (`memory::measured_account`) must equal the live
//! `optim::total_state_bytes` of a freshly built bank, parameter set
//! by parameter set. This is what makes the memory columns of the
//! benches trustworthy — they are analytic, but pinned to the bytes
//! the optimizer actually holds.

use gwt::adapt::{selections, AdaptPolicy};
use gwt::config::{InnerSpec, OptSpec, TrainConfig, TransformSpec};
use gwt::memory::{adaptive_live_state_bytes, measured_account, ParamShape};
use gwt::optim::{build_optimizers, total_state_bytes};
use gwt::wavelet::WaveletBasis;

/// The full composition grid plus the standalone specs.
fn all_specs() -> Vec<OptSpec> {
    let mut transforms = vec![TransformSpec::Identity];
    for basis in WaveletBasis::ALL {
        for level in 1..=3 {
            transforms.push(TransformSpec::wavelet(basis, level));
        }
    }
    for denom in [4, 8] {
        transforms.push(TransformSpec::LowRank { rank_denom: denom });
        transforms.push(TransformSpec::RandomProj { rank_denom: denom });
    }
    for policy in AdaptPolicy::ALL {
        // Freshly built adaptive banks sit at the init selection,
        // which is what the accountant's state_bytes column predicts.
        transforms.push(TransformSpec::Adaptive { policy });
    }
    let inners = [
        InnerSpec::Adam,
        InnerSpec::Adam8bit,
        InnerSpec::AdamMini,
        InnerSpec::SgdM,
    ];
    let mut specs = Vec::new();
    for t in transforms {
        for i in inners {
            specs.push(OptSpec::composed(t, i));
        }
    }
    specs.push(OptSpec::Muon);
    specs.push(OptSpec::lora(4));
    specs.push(OptSpec::lora(8));
    specs
}

fn preset_shapes(name: &str) -> Vec<ParamShape> {
    gwt::config::presets::find(name).unwrap().param_shapes()
}

#[test]
fn measured_equals_analytic_for_every_spec_on_presets() {
    for preset in ["nano", "micro", "gpt-nano"] {
        let shapes = preset_shapes(preset);
        for spec in all_specs() {
            let cfg = TrainConfig {
                preset: preset.into(),
                optimizer: spec,
                ..Default::default()
            };
            let bank = build_optimizers(&shapes, &cfg, None)
                .unwrap_or_else(|e| panic!("{preset} {spec:?}: {e:#}"));
            let live = total_state_bytes(&bank);
            let analytic = measured_account(&shapes, spec).state_bytes;
            assert_eq!(
                live, analytic,
                "{preset} {spec:?}: measured {live} != analytic {analytic}"
            );
        }
    }
}

#[test]
fn measured_parity_survives_training_steps() {
    // State bytes are static for every method except GaLore's lazily
    // materialized projection — which the accountant anticipates.
    // After stepping, measured and analytic must still agree.
    use gwt::rng::Rng;
    use gwt::tensor::Tensor;
    let shapes = preset_shapes("nano");
    for spec in ["gwt-2+adam8bit", "galore-4+sgdm", "apollo-4", "gwt-db4-2+sgdm"] {
        let opt = OptSpec::parse(spec).unwrap();
        let cfg = TrainConfig { optimizer: opt, ..Default::default() };
        let mut bank = build_optimizers(&shapes, &cfg, None).unwrap();
        let mut rng = Rng::new(3);
        let mut ws: Vec<Tensor> = shapes
            .iter()
            .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
            .collect();
        for _ in 0..2 {
            let grads: Vec<Tensor> = shapes
                .iter()
                .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
                .collect();
            gwt::optim::step_bank(&mut bank, &mut ws, &grads, 0.01, &gwt::pool::Sharding::Serial);
        }
        assert_eq!(
            total_state_bytes(&bank),
            measured_account(&shapes, opt).state_bytes,
            "{spec}"
        );
    }
}

#[test]
fn adaptive_live_parity_after_forced_migrations() {
    // The accountant row the adaptive subsystem adds: a single
    // build-time number goes stale after a re-selection, so the live
    // account is parameterized by the bank's current selections —
    // and must equal the measured bank bytes after ANY migration
    // sequence, remapped or reset, for every inner.
    let shapes = preset_shapes("nano");
    for spec in ["adapt-greedy+adam", "adapt-greedy+sgdm", "adapt-greedy+adam8bit"]
    {
        let opt = OptSpec::parse(spec).unwrap();
        let cfg = TrainConfig { optimizer: opt, ..Default::default() };
        let mut bank = build_optimizers(&shapes, &cfg, None).unwrap();
        // Build-time parity (also covered by the grid test above).
        assert_eq!(
            total_state_bytes(&bank),
            measured_account(&shapes, opt).state_bytes,
            "{spec} at build"
        );
        // Force a mixed migration pattern: alternate targets across
        // the adaptive params.
        let mut i = 0usize;
        for p in bank.iter_mut() {
            if let Some(a) = p.adaptive() {
                let (basis, level) = if i % 2 == 0 {
                    (WaveletBasis::Db4, 3)
                } else {
                    (WaveletBasis::Haar, 1)
                };
                a.migrate(basis, level);
                i += 1;
            }
        }
        assert!(i > 0, "{spec}: no adaptive params found");
        let live = total_state_bytes(&bank);
        let analytic =
            adaptive_live_state_bytes(&shapes, opt, &selections(&mut bank));
        assert_eq!(live, analytic, "{spec} after migration");
        // The worst-case (budget) column bounds every selection.
        let worst = measured_account(&shapes, opt).worst_state_bytes;
        assert!(live <= worst, "{spec}: live {live} > worst {worst}");
    }
}

#[test]
fn acceptance_compositions_report_their_savings() {
    // The two acceptance pairs: state-byte reductions vs `gwt-2+adam`
    // reported by the accountant AND verified against the measured
    // bank, on the trainable nano preset.
    let shapes = preset_shapes("nano");
    let bytes = |s: &str| {
        let opt = OptSpec::parse(s).unwrap();
        let cfg = TrainConfig { optimizer: opt, ..Default::default() };
        let live = total_state_bytes(&build_optimizers(&shapes, &cfg, None).unwrap());
        let analytic = measured_account(&shapes, opt).state_bytes;
        assert_eq!(live, analytic, "{s}");
        live
    };
    let baseline = bytes("gwt-2+adam");
    let with_8bit = bytes("gwt-2+adam8bit");
    let with_sgdm = bytes("gwt-db4-2+sgdm");
    assert!(
        with_8bit < baseline,
        "gwt-2+adam8bit {with_8bit} must undercut gwt-2+adam {baseline}"
    );
    assert!(
        with_sgdm < baseline,
        "gwt-db4-2+sgdm {with_sgdm} must undercut gwt-2+adam {baseline}"
    );
    println!(
        "state bytes: gwt-2+adam {baseline}, gwt-2+adam8bit {with_8bit} \
         (-{:.0}%), gwt-db4-2+sgdm {with_sgdm} (-{:.0}%)",
        100.0 * (1.0 - with_8bit as f64 / baseline as f64),
        100.0 * (1.0 - with_sgdm as f64 / baseline as f64),
    );
}
