//! Cross-replica determinism for the wavelet-domain DDP subsystem
//! (`gwt::ddp`): the three pinned axes from `docs/ddp.md`.
//!
//! 1. Full-band replicated jobs are bitwise the legacy `dp_workers`
//!    path — `GradReducer` delegates to `combine_grads` verbatim.
//! 2. A replicated job at fixed R is bit-identical across the thread
//!    grid and across `GWT_SIMD` {scalar, auto} — replicas shard by
//!    index, the tree reduction order is fixed, and the coefficient
//!    step enters the bank through the same per-row kernels.
//! 3. The communication ledger matches the plan exactly:
//!    (R-1) x payload x 4 bytes per parameter per combine, with the
//!    approximation band exactly 2^level smaller than full-band.
//! 4. Error feedback (`ddp_error_feedback = on`): the EF-on
//!    trajectory is pinned across the same R x threads x SIMD grid,
//!    survives suspend/resume with live residual buffers, and closes
//!    at least half of the full-band-vs-approx convergence gap on a
//!    decaying-noise quadratic.
//!
//! Synthetic sources throughout — no PJRT artifacts needed.

use gwt::adapt::AdaptiveOpt;
use gwt::config::{DdpReduce, OptSpec, TrainConfig};
use gwt::ddp::GradReducer;
use gwt::memory::ParamShape;
use gwt::optim::{build_optimizers, step_bank, step_bank_mixed};
use gwt::pool::Sharding;
use gwt::rng::Rng;
use gwt::serve::{JobEngine, JobSource, JobState, SyntheticSource};
use gwt::tensor::Tensor;
use gwt::testing::test_thread_grid;
use gwt::wavelet::kernels::{self, SimdMode};
use gwt::wavelet::WaveletBasis;

fn cfg(opt: OptSpec, steps: usize) -> TrainConfig {
    TrainConfig {
        preset: "nano".into(),
        optimizer: opt,
        steps,
        eval_every: steps,
        ..Default::default()
    }
}

/// Run a single synthetic job to one round short of completion (so
/// live state is readable), then finish. Returns (per-step loss bits,
/// param bits, final loss bits) — the same probe as job_engine.rs.
fn run_solo(threads: usize, job_cfg: &TrainConfig) -> (Vec<u32>, Vec<u32>, u32) {
    let mut e = JobEngine::new(None, threads, 0.0);
    e.submit("solo", job_cfg.clone(), 0, JobSource::Synthetic).unwrap();
    for _ in 0..job_cfg.steps - 1 {
        e.run_round().unwrap();
    }
    let state = e.job_state("solo").unwrap();
    let losses: Vec<u32> =
        state.curve.points.iter().map(|p| p.loss.to_bits()).collect();
    let params: Vec<u32> = state
        .params
        .iter()
        .flat_map(|t| t.data().iter().map(|x| x.to_bits()))
        .collect();
    e.run_to_completion().unwrap();
    let final_bits = e.summaries()[0].final_loss.to_bits();
    (losses, params, final_bits)
}

fn param_bits(params: &[Tensor]) -> Vec<u32> {
    params
        .iter()
        .flat_map(|t| t.data().iter().map(|x| x.to_bits()))
        .collect()
}

#[test]
fn full_band_replicas_match_legacy_dp_workers_bitwise() {
    // `replicas = R` in full-band mode and `dp_workers = R` occupy the
    // same data-shard axis: identical synthetic batch streams (the
    // source keys its RNG by shard index over `round_width()`),
    // identical tree reduction through `combine_grads`. The two
    // configs must produce the same trajectory to the last bit.
    let mut rep = cfg(OptSpec::gwt(2), 6);
    rep.grad_accum = 2;
    rep.replicas = 4;
    rep.ddp_reduce = DdpReduce::Full;
    let mut legacy = cfg(OptSpec::gwt(2), 6);
    legacy.grad_accum = 2;
    legacy.dp_workers = 4;

    let (loss_r, params_r, final_r) = run_solo(2, &rep);
    let (loss_l, params_l, final_l) = run_solo(2, &legacy);
    assert_eq!(loss_r, loss_l, "full-band replicas vs dp_workers: loss");
    assert_eq!(params_r, params_l, "full-band replicas vs dp_workers: params");
    assert_eq!(final_r, final_l, "full-band replicas vs dp_workers: final");
}

#[test]
fn approx_band_reduction_changes_the_trajectory() {
    // Guard against a vacuous grid test: in auto mode a gwt-2 job's
    // eligible parameters reduce only the approximation band, so the
    // weights must diverge from the full-band run (detail-band shard
    // noise is dropped before the optimizer sees it).
    let mut auto_c = cfg(OptSpec::gwt(2), 4);
    auto_c.replicas = 4;
    let mut full_c = auto_c.clone();
    full_c.ddp_reduce = DdpReduce::Full;
    let (_, params_auto, _) = run_solo(1, &auto_c);
    let (_, params_full, _) = run_solo(1, &full_c);
    assert_ne!(
        params_auto, params_full,
        "approx-band mode must actually engage the compressed reduce"
    );
}

#[test]
fn replica_grid_bit_identical_across_threads_and_simd() {
    // The tentpole pin: for each replica count, the trajectory under
    // the compressed reduce is a pure function of the config — thread
    // count and SIMD dispatch are throughput knobs only. Reference is
    // serial + forced-scalar kernels; the grid runs every thread count
    // under both kernel tables.
    for r in [1usize, 2, 4] {
        let mut c = cfg(OptSpec::gwt(2), 4);
        c.grad_accum = 2;
        c.replicas = r;
        kernels::set_mode(SimdMode::Scalar);
        let (loss0, params0, final0) = run_solo(1, &c);
        for (label, mode) in
            [("scalar", SimdMode::Scalar), ("auto", SimdMode::Auto)]
        {
            kernels::set_mode(mode);
            for threads in test_thread_grid() {
                let (loss, params, fin) = run_solo(threads, &c);
                assert_eq!(
                    loss, loss0,
                    "r={r} simd={label} threads={threads}: loss bits"
                );
                assert_eq!(
                    params, params0,
                    "r={r} simd={label} threads={threads}: param bits"
                );
                assert_eq!(
                    fin, final0,
                    "r={r} simd={label} threads={threads}: final loss"
                );
            }
        }
        kernels::set_mode(kernels::mode_from_env());
    }
}

#[test]
fn db4_replicas_bit_identical() {
    // Basis spot-check: the approx-band forward uses the same
    // basis-dispatched row kernel as the optimizer, so Db4 replicas
    // pin the same way Haar does.
    let mut c = cfg(OptSpec::gwt_basis(WaveletBasis::Db4, 2), 4);
    c.replicas = 2;
    kernels::set_mode(SimdMode::Scalar);
    let (loss0, params0, final0) = run_solo(1, &c);
    kernels::set_mode(SimdMode::Auto);
    let (loss, params, fin) = run_solo(4, &c);
    kernels::set_mode(kernels::mode_from_env());
    assert_eq!(loss, loss0, "db4 replicas: loss bits");
    assert_eq!(params, params0, "db4 replicas: param bits");
    assert_eq!(fin, final0, "db4 replicas: final loss");
}

#[test]
fn adaptive_replicas_with_forced_migration_bit_identical() {
    // Adaptive specs always reduce full-band (the probe needs
    // weight-domain gradients), and migrations happen post-step — a
    // replicated adaptive job with a mid-run migration must still be
    // bit-identical across the whole dispatcher grid.
    let mut c = cfg(OptSpec::parse("adapt-greedy+adam").unwrap(), 6);
    c.replicas = 2;
    let run = |sharding: &Sharding| -> (Vec<u32>, Vec<u32>) {
        let src = SyntheticSource::new(&c).unwrap();
        let mut js =
            JobState::new(c.clone(), Box::new(src), None, sharding).unwrap();
        let mut loss_bits = Vec::new();
        for step in 1..=c.steps {
            loss_bits.push(js.step_once(sharding).unwrap().to_bits());
            if step == 3 {
                // Force the same migration on every adaptive engine,
                // identically in every run — state re-shaping mid-job.
                let mut migrated = 0usize;
                for opt in js.bank.iter_mut() {
                    if let Some(a) = opt.adaptive() {
                        let _ = a.migrate(WaveletBasis::Db4, 3);
                        migrated += 1;
                    }
                }
                assert!(migrated > 0, "adaptive bank exposes no engines");
            }
        }
        (loss_bits, param_bits(&js.params))
    };
    let (loss0, params0) = run(&Sharding::Serial);
    for threads in test_thread_grid() {
        for sharding in [Sharding::pool(threads), Sharding::Scoped(threads)] {
            let (loss, params) = run(&sharding);
            assert_eq!(loss, loss0, "{sharding:?}: loss bits");
            assert_eq!(params, params0, "{sharding:?}: param bits");
        }
    }
}

#[test]
fn comm_ledger_matches_plan_accounting() {
    // The per-step communication record is exactly
    // grad_accum x sum_p (R-1) x payload_p x 4 bytes, where payload is
    // the approximation band for planned parameters and the full
    // element count for the rest — and the planned band is exactly
    // 2^level smaller than its full-band counterpart.
    let mut c = cfg(OptSpec::gwt(2), 3);
    c.replicas = 4;
    c.grad_accum = 2;
    let sharding = Sharding::Serial;
    let src = SyntheticSource::new(&c).unwrap();
    let mut js =
        JobState::new(c.clone(), Box::new(src), None, &sharding).unwrap();
    for _ in 0..c.steps {
        js.step_once(&sharding).unwrap();
    }

    // The spec is static, so the post-run plan equals every step's.
    let plan = js.reducer.plan(&js.bank, &js.shapes);
    assert!(
        plan.iter().any(|p| p.is_some()),
        "gwt-2 replicas must plan at least one approx-band reduction"
    );
    let (mut moved, mut full) = (0usize, 0usize);
    let (mut elig_elems, mut elig_payload) = (0usize, 0usize);
    for (p, s) in plan.iter().zip(&js.shapes) {
        let numel = s.numel();
        let payload = match p {
            Some(bp) => bp.rows * bp.approx_cols(),
            None => numel,
        };
        moved += (c.replicas - 1) * payload * 4;
        full += (c.replicas - 1) * numel * 4;
        if let Some(bp) = p {
            elig_elems += numel;
            elig_payload += bp.rows * bp.approx_cols();
        }
    }
    assert_eq!(
        elig_elems,
        4 * elig_payload,
        "level-2 approx band must be exactly 2^2 smaller"
    );

    let per_step_moved = c.grad_accum * moved;
    let per_step_full = c.grad_accum * full;
    assert_eq!(js.reducer.comm.records.len(), c.steps);
    for (i, rec) in js.reducer.comm.records.iter().enumerate() {
        assert_eq!(rec.step, i + 1);
        assert_eq!(rec.bytes, per_step_moved, "step {} moved bytes", i + 1);
        assert_eq!(rec.full_bytes, per_step_full, "step {} full bytes", i + 1);
    }
    let ratio = js.reducer.comm.compression_ratio().unwrap();
    assert!(
        ratio > 1.5 && ratio < 4.0,
        "nano gwt-2 overall ratio (eligible 4x, diluted by embeddings \
         and norms): {ratio}"
    );
}

#[test]
fn single_replica_keeps_the_ledger_empty() {
    let c = cfg(OptSpec::gwt(2), 3);
    let sharding = Sharding::Serial;
    let src = SyntheticSource::new(&c).unwrap();
    let mut js =
        JobState::new(c.clone(), Box::new(src), None, &sharding).unwrap();
    for _ in 0..c.steps {
        js.step_once(&sharding).unwrap();
    }
    assert!(js.reducer.comm.records.is_empty());
}

#[test]
fn error_feedback_changes_the_approx_trajectory() {
    // Vacuity guard for the EF battery: EF-on must actually engage
    // (diverge from EF-off), and remain distinct from full-band (the
    // detail bands arrive one combine late, not instantly).
    let mut off = cfg(OptSpec::gwt(2), 4);
    off.replicas = 4;
    let mut on = off.clone();
    on.ddp_error_feedback = true;
    let (_, p_off, _) = run_solo(1, &off);
    let (_, p_on, _) = run_solo(1, &on);
    assert_ne!(p_on, p_off, "error feedback must engage the reduce");
    let mut full = off.clone();
    full.ddp_reduce = DdpReduce::Full;
    let (_, p_full, _) = run_solo(1, &full);
    assert_ne!(p_on, p_full, "EF is delayed delivery, not full-band");
}

#[test]
fn ef_grid_bit_identical_across_threads_and_simd() {
    // EF-on trajectories are a pure function of the config, like the
    // EF-off grid pin: both tree reductions (wire band + residuals)
    // ride the fixed ascending-replica order, and residual capture is
    // per-row independent. Reference is serial + forced-scalar.
    for r in [2usize, 4] {
        let mut c = cfg(OptSpec::gwt(2), 4);
        c.grad_accum = 2;
        c.replicas = r;
        c.ddp_error_feedback = true;
        kernels::set_mode(SimdMode::Scalar);
        let (loss0, params0, final0) = run_solo(1, &c);
        for (label, mode) in
            [("scalar", SimdMode::Scalar), ("auto", SimdMode::Auto)]
        {
            kernels::set_mode(mode);
            for threads in test_thread_grid() {
                let (loss, params, fin) = run_solo(threads, &c);
                assert_eq!(
                    loss, loss0,
                    "ef r={r} simd={label} threads={threads}: loss bits"
                );
                assert_eq!(
                    params, params0,
                    "ef r={r} simd={label} threads={threads}: param bits"
                );
                assert_eq!(
                    fin, final0,
                    "ef r={r} simd={label} threads={threads}: final loss"
                );
            }
        }
        kernels::set_mode(kernels::mode_from_env());
    }
}

#[test]
fn ef_suspend_resume_with_live_residuals_bit_identical() {
    // Residuals are load-bearing state: a suspend after step 3 has
    // live buffers, and the resumed run must replay the uninterrupted
    // trajectory to the last bit.
    let mut c = cfg(OptSpec::gwt(2), 6);
    c.replicas = 2;
    c.ddp_error_feedback = true;
    let sharding = Sharding::Serial;
    let src = SyntheticSource::new(&c).unwrap();
    let mut a =
        JobState::new(c.clone(), Box::new(src), None, &sharding).unwrap();
    let mut loss_a = Vec::new();
    for _ in 0..c.steps {
        loss_a.push(a.step_once(&sharding).unwrap().to_bits());
    }
    // Interrupted twin: 3 steps, snapshot, restore into a fresh job.
    let src = SyntheticSource::new(&c).unwrap();
    let mut b1 =
        JobState::new(c.clone(), Box::new(src), None, &sharding).unwrap();
    for _ in 0..3 {
        b1.step_once(&sharding).unwrap();
    }
    assert!(
        b1.reducer.ef_state_bytes() > 0,
        "no live residuals to checkpoint"
    );
    let mut ck = b1.snapshot().unwrap();
    assert!(
        ck.tensors.keys().any(|k| k.starts_with("ddp::ef::")),
        "snapshot must carry the EF buffers"
    );
    let src = SyntheticSource::new(&c).unwrap();
    let mut b2 =
        JobState::new(c.clone(), Box::new(src), None, &sharding).unwrap();
    b2.restore(&ck).unwrap();
    let mut loss_b = Vec::new();
    for _ in 0..3 {
        loss_b.push(b2.step_once(&sharding).unwrap().to_bits());
    }
    assert_eq!(&loss_a[3..], &loss_b[..], "resumed loss bits");
    assert_eq!(param_bits(&a.params), param_bits(&b2.params));
    // Control: stripping the EF tensors from the checkpoint must
    // change the resumed trajectory — the zero cold start silently
    // drops one combine's detail energy.
    ck.tensors.retain(|k, _| !k.starts_with("ddp::ef::"));
    let src = SyntheticSource::new(&c).unwrap();
    let mut b3 =
        JobState::new(c.clone(), Box::new(src), None, &sharding).unwrap();
    b3.restore(&ck).unwrap();
    for _ in 0..3 {
        b3.step_once(&sharding).unwrap();
    }
    assert_ne!(
        param_bits(&a.params),
        param_bits(&b3.params),
        "EF buffers must be load-bearing in the checkpoint"
    );
}

#[test]
fn ef_closes_the_full_band_convergence_gap() {
    // Decaying-noise quadratic: each replica reports
    // grad = (w - target) + noise_r with per-step-decaying noise, and
    // the loss is measured directly as ||w - target||_F (a pure
    // function of the params, not fabricated by a source). Full-band
    // converges to the target; approx-only never moves the detail
    // components of the error (their update coefficients are exactly
    // zero); EF delivers them one combine late and must close at
    // least half the gap.
    let shapes = vec![ParamShape {
        name: "layers.00.attn.wq".into(),
        shape: vec![16, 64],
        eligible: true,
    }];
    let run = |reduce: DdpReduce, ef: bool| -> f64 {
        let c = TrainConfig {
            optimizer: OptSpec::gwt(2),
            replicas: 4,
            ddp_reduce: reduce,
            ddp_error_feedback: ef,
            ..Default::default()
        };
        let mut bank = build_optimizers(&shapes, &c, None).unwrap();
        let mut rng = Rng::new(77);
        let mut w: Vec<Tensor> = shapes
            .iter()
            .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
            .collect();
        let target: Vec<Tensor> = shapes
            .iter()
            .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
            .collect();
        let mut reducer = GradReducer::new(&c);
        let plan = reducer.plan(&bank, &shapes);
        let flags: Vec<bool> = plan.iter().map(|p| p.is_some()).collect();
        let sharding = Sharding::Serial;
        for step in 0..100u64 {
            let scale = 0.5 * 0.9f32.powi(step as i32);
            let worker_grads: Vec<Vec<Vec<f32>>> = (0..c.replicas)
                .map(|r| {
                    let mut nrng = Rng::new(1000 + step * 17 + r as u64);
                    w.iter()
                        .zip(&target)
                        .map(|(wi, ti)| {
                            let noise =
                                nrng.normal_vec(wi.data().len(), scale);
                            wi.data()
                                .iter()
                                .zip(ti.data())
                                .zip(&noise)
                                .map(|((a, b), n)| a - b + n)
                                .collect()
                        })
                        .collect()
                })
                .collect();
            let combined =
                reducer.combine(worker_grads, &plan, &sharding).unwrap();
            let grads: Vec<Tensor> = combined
                .into_iter()
                .zip(&shapes)
                .map(|(g, s)| Tensor::new(&s.shape, g))
                .collect();
            if flags.iter().any(|&f| f) {
                step_bank_mixed(
                    &mut bank, &mut w, &grads, &flags, 0.05, &sharding,
                );
            } else {
                step_bank(&mut bank, &mut w, &grads, 0.05, &sharding);
            }
        }
        w.iter()
            .zip(&target)
            .flat_map(|(wi, ti)| {
                wi.data()
                    .iter()
                    .zip(ti.data())
                    .map(|(a, b)| ((a - b) as f64).powi(2))
            })
            .sum::<f64>()
            .sqrt()
    };
    let full = run(DdpReduce::Full, false);
    let approx = run(DdpReduce::Auto, false);
    let ef = run(DdpReduce::Auto, true);
    let gap = approx - full;
    assert!(
        gap > 0.0,
        "dropping detail bands must cost accuracy: approx {approx:.4} \
         vs full {full:.4}"
    );
    assert!(ef < approx, "EF-on ({ef:.4}) must beat EF-off ({approx:.4})");
    assert!(
        approx - ef >= 0.5 * gap,
        "EF must close at least half the full-band gap: closed \
         {:.4} of {gap:.4} (full {full:.4}, approx {approx:.4}, ef {ef:.4})",
        approx - ef
    );
}

#[test]
fn coeff_domain_step_matches_weight_domain_step_bitwise() {
    // The seam the compressed reduce feeds: stepping the bank with
    // forward-transformed gradients through `step_bank_mixed` must be
    // bit-identical to stepping with weight-domain gradients — the
    // fused kernel's coefficient entry point is the exact tail of its
    // weight entry point after `fwd_row`.
    let shapes = vec![
        ParamShape {
            name: "layers.00.attn.wq".into(),
            shape: vec![16, 64],
            eligible: true,
        },
        ParamShape { name: "norm".into(), shape: vec![16], eligible: false },
    ];
    let specs = [
        OptSpec::gwt(2),
        OptSpec::gwt_basis(WaveletBasis::Db4, 2),
        OptSpec::parse("gwt-2+adam").unwrap(),
        // The generic Composed seam: same contract as the fused
        // engine, for every inner it reaches.
        OptSpec::parse("gwt-2+adam8bit").unwrap(),
        OptSpec::parse("gwt-2+adam-mini").unwrap(),
        OptSpec::parse("gwt-db4-2+sgdm").unwrap(),
    ];
    for spec in specs {
        let cfg = TrainConfig { optimizer: spec, ..Default::default() };
        for sharding in [Sharding::Serial, Sharding::pool(4)] {
            let mut bank_w = build_optimizers(&shapes, &cfg, None).unwrap();
            let mut bank_c = build_optimizers(&shapes, &cfg, None).unwrap();
            let (basis, level) = bank_w[0]
                .coeff_band()
                .expect("eligible gwt param must expose the coeff seam");
            let mut rng = Rng::new(11);
            let mut w_a: Vec<Tensor> = shapes
                .iter()
                .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
                .collect();
            let mut w_b = w_a.clone();
            let flags: Vec<bool> =
                shapes.iter().map(|s| s.eligible && s.shape.len() == 2).collect();
            for step in 0..3u64 {
                let mut grng = Rng::new(50 + step);
                let grads: Vec<Tensor> = shapes
                    .iter()
                    .map(|s| Tensor::randn(&s.shape, 1.0, &mut grng))
                    .collect();
                let coeff_grads: Vec<Tensor> = grads
                    .iter()
                    .zip(&shapes)
                    .zip(&flags)
                    .map(|((g, s), &f)| {
                        if f {
                            Tensor::new(
                                &s.shape,
                                basis.fwd(
                                    g.data(),
                                    s.shape[0],
                                    s.shape[1],
                                    level,
                                ),
                            )
                        } else {
                            g.clone()
                        }
                    })
                    .collect();
                let sa = step_bank(&mut bank_w, &mut w_a, &grads, 0.01, &sharding);
                let sb = step_bank_mixed(
                    &mut bank_c,
                    &mut w_b,
                    &coeff_grads,
                    &flags,
                    0.01,
                    &sharding,
                );
                assert_eq!(sa.len(), sb.len());
                for (i, (a, b)) in sa.iter().zip(&sb).enumerate() {
                    assert_eq!(
                        a.update_norm.to_bits(),
                        b.update_norm.to_bits(),
                        "{spec:?} {sharding:?} step={step} param {i} norm"
                    );
                    assert_eq!(
                        a.limiter_scale.to_bits(),
                        b.limiter_scale.to_bits(),
                        "{spec:?} {sharding:?} step={step} param {i} scale"
                    );
                }
            }
            for (i, (a, b)) in w_a.iter().zip(&w_b).enumerate() {
                assert_eq!(
                    a.data(),
                    b.data(),
                    "{spec:?} {sharding:?} param {} ({})",
                    i,
                    shapes[i].name
                );
            }
        }
    }
}
