//! Runtime kernel selection: one [`KernelDispatch`] table of the four
//! level kernels, chosen once from CPU-feature detection (and the
//! `GWT_SIMD` override) and cached in an atomic pointer.
//!
//! Selection policy, in precedence order:
//!
//! 1. [`set_mode`] — what the CLI calls after config resolution
//!    (`TrainConfig::resolve_simd`, which folds in the `simd` config
//!    key and the `GWT_SIMD` env var);
//! 2. the `GWT_SIMD` env var (`scalar` | `auto`), read lazily on
//!    first kernel use when [`set_mode`] was never called (tests,
//!    benches, library embedders);
//! 3. `auto`: AVX2 when `is_x86_feature_detected!("avx2")` holds on
//!    x86_64, NEON unconditionally on aarch64 (baseline ISA), scalar
//!    everywhere else.
//!
//! Because every table is bit-identical on every input (the module
//! contract), a racing `set_mode`/`active` pair is benign: whichever
//! table a worker observes, the output bits are the same.

use std::sync::atomic::{AtomicPtr, Ordering};

/// A level-kernel entry: transform `row` (current level's width) in
/// place using `scratch` (len >= row.len()).
pub type LevelKernel = fn(&mut [f32], &mut [f32]);

/// One selectable implementation set of the four row-level kernels.
pub struct KernelDispatch {
    /// ISA label for summaries/benches: `scalar` | `avx2` | `neon`.
    pub label: &'static str,
    pub haar_fwd_level: LevelKernel,
    pub haar_inv_level: LevelKernel,
    pub db4_fwd_level: LevelKernel,
    pub db4_inv_level: LevelKernel,
}

static SCALAR: KernelDispatch = KernelDispatch {
    label: "scalar",
    haar_fwd_level: super::haar_fwd_level_scalar,
    haar_inv_level: super::haar_inv_level_scalar,
    db4_fwd_level: super::db4_fwd_level_scalar,
    db4_inv_level: super::db4_inv_level_scalar,
};

#[cfg(target_arch = "x86_64")]
static AVX2: KernelDispatch = KernelDispatch {
    label: "avx2",
    haar_fwd_level: super::haar_simd::avx2::haar_fwd_level,
    haar_inv_level: super::haar_simd::avx2::haar_inv_level,
    db4_fwd_level: super::db4_simd::avx2::db4_fwd_level,
    db4_inv_level: super::db4_simd::avx2::db4_inv_level,
};

#[cfg(target_arch = "aarch64")]
static NEON: KernelDispatch = KernelDispatch {
    label: "neon",
    haar_fwd_level: super::haar_simd::neon::haar_fwd_level,
    haar_inv_level: super::haar_simd::neon::haar_inv_level,
    db4_fwd_level: super::db4_simd::neon::db4_fwd_level,
    db4_inv_level: super::db4_simd::neon::db4_inv_level,
};

/// The portable scalar table (always available; the bit-identity
/// reference the SIMD batteries compare against).
pub fn scalar() -> &'static KernelDispatch {
    &SCALAR
}

/// The best table `auto` would pick on this host.
fn best() -> &'static KernelDispatch {
    #[cfg(target_arch = "x86_64")]
    if std::arch::is_x86_feature_detected!("avx2") {
        return &AVX2;
    }
    #[cfg(target_arch = "aarch64")]
    return &NEON;
    #[cfg(not(target_arch = "aarch64"))]
    &SCALAR
}

/// The SIMD table this host supports, if any — `None` means `auto`
/// resolves to scalar (tests degrade to scalar==scalar there).
pub fn simd() -> Option<&'static KernelDispatch> {
    let b = best();
    if std::ptr::eq(b, &SCALAR) {
        None
    } else {
        Some(b)
    }
}

/// Kernel-selection mode: the `simd` config key / `GWT_SIMD` env var.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SimdMode {
    /// Force the portable scalar kernels (A/B tests, CI matrix,
    /// bit-identity triage).
    Scalar,
    /// Pick the best detected ISA (scalar when none).
    #[default]
    Auto,
}

impl SimdMode {
    pub fn parse(s: &str) -> anyhow::Result<SimdMode> {
        match s.trim().to_lowercase().as_str() {
            "scalar" => Ok(SimdMode::Scalar),
            "auto" => Ok(SimdMode::Auto),
            other => anyhow::bail!("simd must be scalar|auto, got '{other}'"),
        }
    }

    pub const fn label(self) -> &'static str {
        match self {
            SimdMode::Scalar => "scalar",
            SimdMode::Auto => "auto",
        }
    }

    /// The table this mode selects on this host.
    pub fn table(self) -> &'static KernelDispatch {
        match self {
            SimdMode::Scalar => &SCALAR,
            SimdMode::Auto => best(),
        }
    }
}

/// Read the `GWT_SIMD` env override. Like `GWT_TEST_THREADS`, a
/// set-but-invalid value panics instead of silently running `auto`:
/// a pin that doesn't pin would let a `GWT_SIMD=scalar` CI pass go
/// green while still running SIMD.
pub fn mode_from_env() -> SimdMode {
    match std::env::var("GWT_SIMD") {
        Ok(raw) => SimdMode::parse(&raw).unwrap_or_else(|e| panic!("GWT_SIMD: {e}")),
        Err(_) => SimdMode::Auto,
    }
}

static ACTIVE: AtomicPtr<KernelDispatch> = AtomicPtr::new(std::ptr::null_mut());

/// The table every `wavelet` row transform dispatches through.
/// Lazily initialized from [`mode_from_env`] on first use; explicit
/// [`set_mode`] (the CLI's config-resolution hook) overrides.
pub fn active() -> &'static KernelDispatch {
    let p = ACTIVE.load(Ordering::Acquire);
    if p.is_null() {
        let t = mode_from_env().table();
        ACTIVE.store(
            t as *const KernelDispatch as *mut KernelDispatch,
            Ordering::Release,
        );
        return t;
    }
    // Safety: only ever stores pointers to the 'static tables above.
    unsafe { &*p }
}

/// ISA label of the active table (config summaries, bench notes).
pub fn active_label() -> &'static str {
    active().label
}

/// Pin the active table to `mode`'s selection. Called once at CLI
/// startup with the resolved config value; tests use it to force
/// scalar/auto and restore `mode_from_env()` afterwards.
pub fn set_mode(mode: SimdMode) {
    let t = mode.table();
    ACTIVE.store(
        t as *const KernelDispatch as *mut KernelDispatch,
        Ordering::Release,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modes_parse_and_label() {
        assert_eq!(SimdMode::parse("scalar").unwrap(), SimdMode::Scalar);
        assert_eq!(SimdMode::parse("AUTO").unwrap(), SimdMode::Auto);
        assert_eq!(SimdMode::parse(" auto ").unwrap(), SimdMode::Auto);
        assert!(SimdMode::parse("avx512").is_err());
        assert!(SimdMode::parse("").is_err());
        assert_eq!(SimdMode::default(), SimdMode::Auto);
        assert_eq!(SimdMode::Scalar.label(), "scalar");
        assert_eq!(SimdMode::Auto.label(), "auto");
    }

    #[test]
    fn scalar_mode_selects_scalar_table() {
        assert!(std::ptr::eq(SimdMode::Scalar.table(), scalar()));
        assert_eq!(scalar().label, "scalar");
    }

    #[test]
    fn auto_table_is_scalar_or_detected_simd() {
        let t = SimdMode::Auto.table();
        match simd() {
            Some(s) => {
                assert!(std::ptr::eq(t, s));
                assert!(matches!(s.label, "avx2" | "neon"), "{}", s.label);
            }
            None => assert!(std::ptr::eq(t, scalar())),
        }
    }

    #[test]
    fn set_mode_pins_and_restores() {
        // Global state: other tests observe bit-identical tables
        // either way, so flipping here is benign; restore the env
        // resolution at the end regardless.
        set_mode(SimdMode::Scalar);
        assert_eq!(active_label(), "scalar");
        set_mode(SimdMode::Auto);
        assert_eq!(active_label(), SimdMode::Auto.table().label);
        set_mode(mode_from_env());
        assert_eq!(active_label(), mode_from_env().table().label);
    }
}
