//! Basis ablation (paper open problem (a)): Haar vs DB4 across the
//! whole stack, now that `WaveletBasis` is a first-class axis.
//!
//! Part 1 is artifact-free and always runs: approximation-band
//! compression error for both bases on three gradient-like signal
//! classes (the transform-level story — DB4's extra vanishing moment
//! wins on smooth rows, Haar's strict locality wins on blocky rows,
//! white noise is a wash). Part 2 pretrains nano with `gwt-2` vs
//! `gwt-db4-2` on identical data when AOT artifacts are present
//! (the DB4 run takes the rust path; state bytes must match Haar
//! exactly).
//!
//! ci.sh smoke-invokes this bench (Part 1 at minimum), so keep the
//! artifact-free section fast and dependency-free.

use gwt::bench_harness::{
    bench_loader, pretrain, scaled, write_bench_file, write_result, RunSpec,
    TableView,
};
use gwt::config::OptSpec;
use gwt::rng::Rng;
use gwt::runtime::Runtime;
use gwt::wavelet::WaveletBasis;

/// Smooth periodic rows (no wrap discontinuity): DB4's regime.
fn smooth_rows(m: usize, n: usize, rng: &mut Rng) -> Vec<f32> {
    let mut x = vec![0.0f32; m * n];
    for r in 0..m {
        let amp = 1.0 + rng.f32();
        let phase = rng.f32() * std::f32::consts::TAU;
        for j in 0..n {
            let t = j as f32 / n as f32 * std::f32::consts::TAU;
            x[r * n + j] =
                amp * (t + phase).sin() + 0.3 * amp * (2.0 * t + phase).cos();
        }
    }
    x
}

/// Piecewise-constant rows (block width = 2^level): Haar's regime.
fn blocky_rows(m: usize, n: usize, level: usize, rng: &mut Rng) -> Vec<f32> {
    let b = 1usize << level;
    let mut x = vec![0.0f32; m * n];
    for r in 0..m {
        for blk in 0..n / b {
            let v = rng.normal_f32();
            for j in 0..b {
                x[r * n + blk * b + j] = v;
            }
        }
    }
    x
}

fn main() -> anyhow::Result<()> {
    let (m, n) = (32usize, 128usize);
    let mut rng = Rng::new(0x5a51);

    let mut table = TableView::new(
        "Basis ablation — approximation-band compression error (32x128)",
        &["signal", "level", "Haar err", "DB4 err", "DB4/Haar", "winner"],
    );
    let mut claims_ok = true;
    for level in 1..=3usize {
        let cases: [(&str, Vec<f32>); 3] = [
            ("smooth periodic", smooth_rows(m, n, &mut rng)),
            ("blocky", blocky_rows(m, n, level, &mut rng)),
            ("white noise", rng.normal_vec(m * n, 1.0)),
        ];
        for (name, x) in cases {
            let e_haar = WaveletBasis::Haar.lowpass_error(&x, m, n, level);
            let e_db4 = WaveletBasis::Db4.lowpass_error(&x, m, n, level);
            let ratio = e_db4 / e_haar;
            table.row(vec![
                name.into(),
                format!("{level}"),
                format!("{e_haar:.3}"),
                format!("{e_db4:.3}"),
                format!("{ratio:.3}"),
                if ratio < 0.95 {
                    "DB4".into()
                } else if ratio > 1.05 {
                    "Haar".into()
                } else {
                    "tie".into()
                },
            ]);
            // The trade-off behind the paper's choice of Haar — and
            // the reason the basis is worth having as an axis.
            match name {
                "smooth periodic" => claims_ok &= ratio < 1.0,
                "blocky" => claims_ok &= ratio > 1.0,
                _ => {}
            }
        }
    }
    table.print();
    println!(
        "transform-level shape: DB4 wins smooth rows, Haar wins blocky rows [{}]",
        if claims_ok { "OK" } else { "MISS" }
    );

    // Part 2: end-to-end training ablation, only when artifacts exist
    // (the transform-level section above must run everywhere, so no
    // runtime_or_skip process-exit before this point).
    let Ok(rt) = Runtime::load("artifacts") else {
        println!("(skipping training ablation: no artifacts)");
        write_result("fig8_basis_ablation", &table, vec![])?;
        write_bench_file(
            "fig8_basis_ablation",
            &table,
            "transform-level rows only (no compiled artifacts); error \
             ratios, not timings — the bench gate keys on them for \
             presence, not latency",
        )?;
        return Ok(());
    };
    let rt = std::sync::Arc::new(rt);
    let steps = scaled(150);
    let loader = bench_loader("nano", steps, 21);
    let mut train_table = TableView::new(
        "Basis ablation — nano pretraining, identical data",
        &["config", "valid PPL", "state KB", "path"],
    );
    let mut outs = Vec::new();
    for (label, opt) in [
        ("GWT-2 (Haar)", OptSpec::gwt(2)),
        ("GWT-DB4-2", OptSpec::gwt_basis(WaveletBasis::Db4, 2)),
    ] {
        let spec = RunSpec::paper_defaults("nano", opt, steps);
        let out = pretrain(rt.clone(), &spec, &loader);
        println!("  {label:<12} ppl {:.2}", out.valid_ppl);
        train_table.row(vec![
            label.into(),
            format!("{:.2}", out.valid_ppl),
            format!("{:.1}", out.state_bytes as f64 / 1e3),
            if label.contains("DB4") { "rust (no AOT artifact)".into() } else { "auto".into() },
        ]);
        outs.push(out);
    }
    assert_eq!(
        outs[0].state_bytes, outs[1].state_bytes,
        "basis swap must not change optimizer-state bytes"
    );
    train_table.print();
    println!(
        "state parity: {} KB both bases [OK]; ppl Haar {:.2} vs DB4 {:.2}",
        outs[0].state_bytes as f64 / 1e3,
        outs[0].valid_ppl,
        outs[1].valid_ppl
    );
    write_result(
        "fig8_basis_ablation",
        &table,
        vec![("training", train_table.to_json())],
    )?;
    write_bench_file(
        "fig8_basis_ablation",
        &table,
        "full run including the nano training ablation",
    )?;
    Ok(())
}
