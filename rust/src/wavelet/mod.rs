//! Wavelet-basis subsystem: the transforms GWT-Adam compresses
//! gradients through, behind one selectable [`WaveletBasis`] axis.
//!
//! Two orthonormal families are implemented today — the paper's
//! 2-tap Haar filters (this file, a rust mirror of
//! `python/compile/kernels/ref.py`) and the 4-tap Daubechies pair
//! ([`db4`], the paper's open problem (a)). Both share one contract:
//!
//! * coefficient layout `[A_l | D_l | D_{l-1} | ... | D_1]` along
//!   rows of length `n`, exactly matching the Python oracle;
//! * an `level`-level transform is defined iff `2^level` divides `n`
//!   ([`check_level`], identical for every basis);
//! * the approximation band after `level` levels has width
//!   `n >> level` ([`approx_width`]), *independent of the basis* —
//!   which is what keeps GWT optimizer-state shapes identical when
//!   the basis is swapped;
//! * perfect reconstruction and energy preservation (orthonormality),
//!   pinned by each family's tests.
//!
//! Consumers dispatch through [`WaveletBasis::fwd_row`] /
//! [`WaveletBasis::inv_row`]: the GWT-Adam rust path (serial and
//! row-sharded — the per-row code is basis-dispatched but identical
//! across workers, preserving the bit-identical determinism
//! contract), the memory accountant's sanity checks, and the
//! Theorem-1 verification tests. The free `haar_*` functions remain
//! as the Haar implementation and for callers pinned to the paper's
//! basis.
//!
//! The innermost per-level loops live in [`kernels`]: scalar, AVX2,
//! and NEON implementations selected once at runtime behind a
//! dispatch table (`GWT_SIMD=scalar|auto` override), all pinned
//! bit-identical — so every row transform here accelerates without
//! any call-site change and the determinism contract is untouched.

pub mod db4;
pub mod kernels;
pub mod theory;

/// A selectable wavelet family for the GWT subsystem.
///
/// Deliberately a small closed enum (not a trait object): every
/// basis must guarantee the module contract above — same layout,
/// same admissibility rule, same `n >> level` approximation width —
/// so optimizer state built for one basis has exactly the shape of
/// any other. Adding a family means adding a variant plus its
/// `fwd_row`/`inv_row` arms, and every layer (config specs, manifest
/// keys, accountant labels, benches) picks it up through this type.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum WaveletBasis {
    /// 2-tap orthonormal Haar pair — the paper's choice: strictly
    /// local, exact on piecewise-constant (blocky) gradients.
    #[default]
    Haar,
    /// 4-tap Daubechies pair (periodic boundaries): one extra
    /// vanishing moment, so the approximation band also absorbs
    /// linear trends within blocks.
    Db4,
}

impl WaveletBasis {
    /// Every supported basis, in spec order (ablation sweeps).
    pub const ALL: [WaveletBasis; 2] = [WaveletBasis::Haar, WaveletBasis::Db4];

    /// Canonical lowercase token used in optimizer specs
    /// (`gwt-db4-2`) and manifest artifact keys.
    pub const fn token(self) -> &'static str {
        match self {
            WaveletBasis::Haar => "haar",
            WaveletBasis::Db4 => "db4",
        }
    }

    /// Human-facing label fragment (`GWT-DB4-2`).
    pub const fn label(self) -> &'static str {
        match self {
            WaveletBasis::Haar => "Haar",
            WaveletBasis::Db4 => "DB4",
        }
    }

    /// The one GWT label-spelling rule, shared by
    /// `config::TransformSpec::label` (hence every spec/accountant
    /// label) and `GwtAdam::label`: Haar keeps the paper's bare
    /// `GWT-l`; every other basis is qualified (`GWT-DB4-l`) so
    /// labels parse back to the same spec.
    pub fn gwt_label(self, level: usize) -> String {
        match self {
            WaveletBasis::Haar => format!("GWT-{level}"),
            b => format!("GWT-{}-{level}", b.label()),
        }
    }

    /// Parse a basis token, case-insensitive. `None` for unknown
    /// tokens (callers decide whether that is an error or "no basis
    /// segment present").
    pub fn parse(s: &str) -> Option<WaveletBasis> {
        match s.trim().to_ascii_lowercase().as_str() {
            "haar" => Some(WaveletBasis::Haar),
            "db4" | "daub4" | "daubechies4" => Some(WaveletBasis::Db4),
            _ => None,
        }
    }

    /// Validate that an `level`-level transform is defined for width
    /// `n`. The admissibility rule (`2^level` divides `n`) is part of
    /// the basis contract and identical for every family.
    pub fn check_level(self, n: usize, level: usize) -> anyhow::Result<()> {
        check_level(n, level)
    }

    /// Width of the approximation band after `level` levels —
    /// basis-independent by construction (each level halves the
    /// band), which is what keeps GWT optimizer-state shapes
    /// identical across bases.
    pub const fn approx_width(self, n: usize, level: usize) -> usize {
        n >> level
    }

    /// Multi-level forward transform of one row, in place, using
    /// `scratch` (len >= row.len()).
    pub fn fwd_row(self, row: &mut [f32], level: usize, scratch: &mut [f32]) {
        match self {
            WaveletBasis::Haar => haar_fwd_row(row, level, scratch),
            WaveletBasis::Db4 => db4::db4_fwd_row(row, level, scratch),
        }
    }

    /// Multi-level inverse transform of one row, in place.
    pub fn inv_row(self, row: &mut [f32], level: usize, scratch: &mut [f32]) {
        match self {
            WaveletBasis::Haar => haar_inv_row(row, level, scratch),
            WaveletBasis::Db4 => db4::db4_inv_row(row, level, scratch),
        }
    }

    /// Forward transform over an `(m, n)` row-major matrix, out of
    /// place (tests / analysis; the optimizer hot path uses
    /// [`WaveletBasis::fwd_row`] with persistent buffers).
    pub fn fwd(self, x: &[f32], m: usize, n: usize, level: usize) -> Vec<f32> {
        match self {
            WaveletBasis::Haar => haar_fwd(x, m, n, level),
            WaveletBasis::Db4 => db4::db4_fwd(x, m, n, level),
        }
    }

    /// Inverse transform over an `(m, n)` row-major matrix, out of
    /// place.
    pub fn inv(self, c: &[f32], m: usize, n: usize, level: usize) -> Vec<f32> {
        match self {
            WaveletBasis::Haar => haar_inv(c, m, n, level),
            WaveletBasis::Db4 => db4::db4_inv(c, m, n, level),
        }
    }

    /// Allocation-free form of [`WaveletBasis::fwd`]: `out` (len
    /// `m*n`) receives the coefficients, `scratch` (len >= `n`) is
    /// caller-owned working space.
    pub fn fwd_into(
        self,
        x: &[f32],
        m: usize,
        n: usize,
        level: usize,
        out: &mut [f32],
        scratch: &mut [f32],
    ) {
        match self {
            WaveletBasis::Haar => haar_fwd_into(x, m, n, level, out, scratch),
            WaveletBasis::Db4 => db4::db4_fwd_into(x, m, n, level, out, scratch),
        }
    }

    /// Allocation-free form of [`WaveletBasis::inv`].
    pub fn inv_into(
        self,
        c: &[f32],
        m: usize,
        n: usize,
        level: usize,
        out: &mut [f32],
        scratch: &mut [f32],
    ) {
        match self {
            WaveletBasis::Haar => haar_inv_into(c, m, n, level, out, scratch),
            WaveletBasis::Db4 => db4::db4_inv_into(c, m, n, level, out, scratch),
        }
    }

    /// Approximation-band compression error `||x − P_l(x)||_F`, where
    /// `P_l` reconstructs from the level-`level` approximation band
    /// alone. This is the *single* basis-dispatched entry point behind
    /// the adaptive probe, the Theorem-1 machinery
    /// (`theory::lowpass_error`), and the basis-ablation tests — it
    /// replaces two earlier per-family implementations (a Haar-only
    /// block-mean form in `theory.rs` and a `db4: bool`-flagged form
    /// in `db4.rs`).
    ///
    /// Because every supported basis is orthonormal, the
    /// reconstruction error equals the energy of the zeroed detail
    /// coefficients, so it is computed from one forward transform —
    /// no inverse, no reconstruction diff. For Haar this equals
    /// `||x − haar_lowpass(x)||_F` (block means; pinned by
    /// `lowpass_equals_zeroed_details`).
    pub fn lowpass_error(self, x: &[f32], m: usize, n: usize, level: usize) -> f64 {
        self.lowpass_error_profile(x, m, n, level)
            .last()
            .copied()
            .unwrap_or(0.0)
    }

    /// [`WaveletBasis::lowpass_error`] at *every* level `1..=max_level`
    /// from a single forward pass per row: the level-`l` approximation
    /// band is nested inside the level-`max_level` coefficients, so
    /// `out[l-1] = ||x − P_l(x)||_F` falls out of one transform plus
    /// band-energy prefix sums. This is the adaptive probe's
    /// statistic — one call per candidate basis covers every candidate
    /// level.
    pub fn lowpass_error_profile(
        self,
        x: &[f32],
        m: usize,
        n: usize,
        max_level: usize,
    ) -> Vec<f64> {
        let mut row_buf = vec![0.0f32; n];
        let mut scratch = vec![0.0f32; n];
        let mut out = vec![0.0f64; max_level];
        self.lowpass_error_profile_into(
            x,
            m,
            n,
            max_level,
            &mut row_buf,
            &mut scratch,
            &mut out,
        );
        out
    }

    /// Scratch-reusing form of [`WaveletBasis::lowpass_error_profile`]
    /// (`row_buf`/`scratch` len >= `n`, `out` len == `max_level`) —
    /// what the adaptive probe calls with its persistent buffers, so
    /// steady-state probing allocates nothing.
    pub fn lowpass_error_profile_into(
        self,
        x: &[f32],
        m: usize,
        n: usize,
        max_level: usize,
        row_buf: &mut [f32],
        scratch: &mut [f32],
        out: &mut [f64],
    ) {
        assert_eq!(x.len(), m * n);
        assert_eq!(out.len(), max_level);
        check_level(n, max_level).expect("invalid level");
        out.fill(0.0);
        if max_level == 0 {
            return;
        }
        for r in 0..m {
            row_buf[..n].copy_from_slice(&x[r * n..(r + 1) * n]);
            self.fwd_row(&mut row_buf[..n], max_level, scratch);
            // Detail band D_l occupies [n>>l, n>>(l-1)); the level-L
            // error energy is the union of bands D_1..D_L, accumulated
            // below via a prefix sum over l.
            for l in 1..=max_level {
                let (lo, hi) = (n >> l, n >> (l - 1));
                out[l - 1] += row_buf[lo..hi]
                    .iter()
                    .map(|v| (*v as f64).powi(2))
                    .sum::<f64>();
            }
        }
        let mut acc = 0.0f64;
        for e in out.iter_mut() {
            acc += *e;
            *e = acc.sqrt();
        }
    }
}

pub const INV_SQRT2: f32 = std::f32::consts::FRAC_1_SQRT_2;

/// Validate that an `level`-level transform is defined for width `n`.
///
/// The range check must come first: `1usize << level` overflows (and
/// panics in debug builds) for `level >= usize::BITS`, so evaluating
/// the divisibility check before the guard turned an invalid-config
/// error into a shift-overflow panic.
pub fn check_level(n: usize, level: usize) -> anyhow::Result<()> {
    if level >= usize::BITS as usize {
        anyhow::bail!("level {level} out of range");
    }
    if level > 0 && (n % (1usize << level)) != 0 {
        anyhow::bail!("width {n} not divisible by 2^level={}", 1usize << level);
    }
    Ok(())
}

/// Forward transform of one row, in place, using `scratch` (len >= n).
///
/// Dispatches through [`kernels::active`] — scalar, AVX2, or NEON
/// level kernels, all bit-identical (see `kernels`' module docs).
pub fn haar_fwd_row(row: &mut [f32], level: usize, scratch: &mut [f32]) {
    kernels::haar_fwd_row_with(kernels::active(), row, level, scratch);
}

/// Inverse transform of one row, in place.
pub fn haar_inv_row(row: &mut [f32], level: usize, scratch: &mut [f32]) {
    kernels::haar_inv_row_with(kernels::active(), row, level, scratch);
}

/// Forward transform over an `(m, n)` row-major matrix, out of place.
pub fn haar_fwd(x: &[f32], m: usize, n: usize, level: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    let mut scratch = vec![0.0f32; n];
    haar_fwd_into(x, m, n, level, &mut out, &mut scratch);
    out
}

/// Allocation-free form of [`haar_fwd`]: `out` (len `m*n`) receives
/// the coefficients, `scratch` (len >= `n`) is caller-owned.
pub fn haar_fwd_into(
    x: &[f32],
    m: usize,
    n: usize,
    level: usize,
    out: &mut [f32],
    scratch: &mut [f32],
) {
    assert_eq!(x.len(), m * n);
    assert_eq!(out.len(), m * n);
    assert!(scratch.len() >= n);
    check_level(n, level).expect("invalid level");
    out.copy_from_slice(x);
    for r in 0..m {
        haar_fwd_row(&mut out[r * n..(r + 1) * n], level, scratch);
    }
}

/// Inverse transform over an `(m, n)` row-major matrix, out of place.
pub fn haar_inv(c: &[f32], m: usize, n: usize, level: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    let mut scratch = vec![0.0f32; n];
    haar_inv_into(c, m, n, level, &mut out, &mut scratch);
    out
}

/// Allocation-free form of [`haar_inv`].
pub fn haar_inv_into(
    c: &[f32],
    m: usize,
    n: usize,
    level: usize,
    out: &mut [f32],
    scratch: &mut [f32],
) {
    assert_eq!(c.len(), m * n);
    assert_eq!(out.len(), m * n);
    assert!(scratch.len() >= n);
    check_level(n, level).expect("invalid level");
    out.copy_from_slice(c);
    for r in 0..m {
        haar_inv_row(&mut out[r * n..(r + 1) * n], level, scratch);
    }
}

/// Block-mean operator `P_l` of the paper's Theorem 1: replaces each
/// consecutive block of `2^level` columns with the block mean.
///
/// Routed through the shared kernel path (forward transform, zero
/// the detail bands, inverse transform) so it rides the same SIMD
/// dispatch as every other consumer; for Haar this equals direct
/// block means up to roundoff (pinned, with an explicit block-mean
/// cross-check, by `lowpass_equals_zeroed_details`).
pub fn haar_lowpass(x: &[f32], m: usize, n: usize, level: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    let mut scratch = vec![0.0f32; n];
    haar_lowpass_into(x, m, n, level, &mut out, &mut scratch);
    out
}

/// Allocation-free form of [`haar_lowpass`].
pub fn haar_lowpass_into(
    x: &[f32],
    m: usize,
    n: usize,
    level: usize,
    out: &mut [f32],
    scratch: &mut [f32],
) {
    assert_eq!(x.len(), m * n);
    assert_eq!(out.len(), m * n);
    assert!(scratch.len() >= n);
    check_level(n, level).expect("invalid level");
    out.copy_from_slice(x);
    if level == 0 {
        return;
    }
    let q = n >> level;
    for r in 0..m {
        let row = &mut out[r * n..(r + 1) * n];
        haar_fwd_row(row, level, scratch);
        row[q..].fill(0.0);
        haar_inv_row(row, level, scratch);
    }
}

/// Width of the approximation band after `level` levels.
pub fn approx_width(n: usize, level: usize) -> usize {
    n >> level
}

/// Maximum admissible level for width `n`: the number of trailing
/// zero bits, i.e. the largest `l` with `2^l | n` — the deepest
/// level [`check_level`] accepts. This is *not* capped at `log2(n)`
/// beyond what divisibility already implies: for `n = 2^k` it equals
/// `log2(n)` exactly (approximation band of width 1), and for
/// `n = 2^k · odd` it is `k`. `n = 0` returns 0 by convention (no
/// admissible transform; `trailing_zeros` alone would say 64).
pub fn max_level(n: usize) -> usize {
    if n == 0 {
        return 0;
    }
    n.trailing_zeros() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testing::approx_eq_slice;

    fn randmat(m: usize, n: usize, seed: u64) -> Vec<f32> {
        Rng::new(seed).normal_vec(m * n, 1.0)
    }

    #[test]
    fn paper_worked_example_level1_and_2() {
        // Paper §III-A explicit 8-element example.
        let x = [1., 2., 3., 4., 5., 6., 7., 8.];
        let c1 = haar_fwd(&x, 1, 8, 1);
        let s2 = std::f32::consts::SQRT_2;
        let want_a1 = [3. / s2, 7. / s2, 11. / s2, 15. / s2];
        let want_d1 = [-1. / s2, -1. / s2, -1. / s2, -1. / s2];
        approx_eq_slice(&c1[..4], &want_a1, 1e-6);
        approx_eq_slice(&c1[4..], &want_d1, 1e-6);

        let c2 = haar_fwd(&x, 1, 8, 2);
        approx_eq_slice(&c2[..2], &[5.0, 13.0], 1e-6); // A2
        approx_eq_slice(&c2[2..4], &[-2.0, -2.0], 1e-6); // D2
    }

    #[test]
    fn perfect_reconstruction_many_shapes() {
        for &(m, n) in &[(1, 2), (3, 8), (16, 64), (5, 96), (2, 1024)] {
            let x = randmat(m, n, (m * n) as u64);
            for level in 0..=max_level(n).min(6) {
                let back = haar_inv(&haar_fwd(&x, m, n, level), m, n, level);
                approx_eq_slice(&back, &x, 1e-4);
            }
        }
    }

    #[test]
    fn energy_preserved() {
        let x = randmat(8, 128, 3);
        for level in [1, 3, 5] {
            let c = haar_fwd(&x, 8, 128, level);
            let ex: f64 = x.iter().map(|v| (*v as f64).powi(2)).sum();
            let ec: f64 = c.iter().map(|v| (*v as f64).powi(2)).sum();
            assert!(((ex - ec) / ex).abs() < 1e-5, "level {level}");
        }
    }

    #[test]
    fn lowpass_equals_zeroed_details() {
        let (m, n, level) = (4, 32, 3);
        let x = randmat(m, n, 9);
        let mut c = haar_fwd(&x, m, n, level);
        let q = n >> level;
        for r in 0..m {
            for j in q..n {
                c[r * n + j] = 0.0;
            }
        }
        let via_zeroing = haar_inv(&c, m, n, level);
        let direct = haar_lowpass(&x, m, n, level);
        approx_eq_slice(&via_zeroing, &direct, 1e-5);
        // haar_lowpass now routes through the kernel path itself, so
        // the comparison above shares its implementation; pin the
        // Theorem-1 semantic (P_l = block means) independently.
        let b = 1usize << level;
        for r in 0..m {
            for k in 0..n / b {
                let mean = x[r * n + k * b..r * n + (k + 1) * b]
                    .iter()
                    .sum::<f32>()
                    / b as f32;
                for j in 0..b {
                    let got = direct[r * n + k * b + j];
                    assert!(
                        (got - mean).abs() <= 1e-4 * (1.0 + mean.abs()),
                        "row {r} block {k}: {got} vs block mean {mean}"
                    );
                }
            }
        }
    }

    #[test]
    fn lowpass_into_matches_allocating_form() {
        let (m, n, level) = (3, 64, 2);
        let x = randmat(m, n, 41);
        let direct = haar_lowpass(&x, m, n, level);
        let mut out = vec![0.0f32; m * n];
        let mut scratch = vec![0.0f32; n];
        haar_lowpass_into(&x, m, n, level, &mut out, &mut scratch);
        assert_eq!(direct, out);
        // Level 0 is the identity.
        haar_lowpass_into(&x, m, n, 0, &mut out, &mut scratch);
        assert_eq!(out, x);
    }

    #[test]
    fn into_variants_match_allocating_forms() {
        let (m, n, level) = (5, 96, 3);
        let x = randmat(m, n, 77);
        let mut scratch = vec![0.0f32; n];
        for b in WaveletBasis::ALL {
            let c = b.fwd(&x, m, n, level);
            let mut c2 = vec![0.0f32; m * n];
            b.fwd_into(&x, m, n, level, &mut c2, &mut scratch);
            assert_eq!(c, c2, "{b:?} fwd");
            let back = b.inv(&c, m, n, level);
            let mut back2 = vec![0.0f32; m * n];
            b.inv_into(&c, m, n, level, &mut back2, &mut scratch);
            assert_eq!(back, back2, "{b:?} inv");
        }
    }

    #[test]
    fn level_zero_is_identity() {
        let x = randmat(3, 10, 1);
        assert_eq!(haar_fwd(&x, 3, 10, 0), x);
        assert_eq!(haar_inv(&x, 3, 10, 0), x);
    }

    #[test]
    fn rejects_bad_widths() {
        assert!(check_level(12, 3).is_err());
        assert!(check_level(12, 2).is_ok());
        assert!(check_level(7, 1).is_err());
    }

    #[test]
    fn rejects_out_of_range_level_without_panicking() {
        // Regression: `1usize << level` used to be evaluated before
        // the range guard, panicking with shift overflow for
        // level >= usize::BITS instead of returning Err.
        assert!(check_level(8, 64).is_err());
        assert!(check_level(8, usize::BITS as usize).is_err());
        assert!(check_level(8, 200).is_err());
        assert!(check_level(8, usize::MAX).is_err());
        // The largest representable level is still validated, not
        // panicked on (width can never satisfy it, so it errors).
        assert!(check_level(8, 63).is_err());
    }

    #[test]
    fn max_level_trailing_zeros() {
        assert_eq!(max_level(64), 6);
        assert_eq!(max_level(96), 5);
        assert_eq!(max_level(7), 0);
        assert_eq!(max_level(0), 0);
    }

    #[test]
    fn max_level_edge_cases_agree_with_doc_and_check_level() {
        // Doc/behavior agreement (the doc used to claim a log2(n)
        // cap, which trailing_zeros never applied): n = 1 and odd n
        // admit no levels; powers of two admit exactly log2(n);
        // 2^k·odd admits exactly k.
        assert_eq!(max_level(1), 0);
        assert_eq!(max_level(3), 0);
        assert_eq!(max_level(2), 1);
        assert_eq!(max_level(1024), 10);
        assert_eq!(max_level(12), 2); // 4·3
        assert_eq!(max_level(160), 5); // 32·5
        // max_level is exactly the deepest level check_level admits.
        for n in [0usize, 1, 2, 3, 6, 7, 8, 12, 64, 96, 160, 1024] {
            let l = max_level(n);
            if n > 0 {
                assert!(check_level(n, l).is_ok(), "n={n} l={l}");
            }
            if n > 1 {
                assert!(check_level(n, l + 1).is_err(), "n={n} l={}", l + 1);
            }
        }
    }

    #[test]
    fn basis_token_label_parse_roundtrip() {
        for b in WaveletBasis::ALL {
            assert_eq!(WaveletBasis::parse(b.token()), Some(b));
            assert_eq!(WaveletBasis::parse(b.label()), Some(b));
            assert_eq!(WaveletBasis::parse(&b.token().to_uppercase()), Some(b));
        }
        assert_eq!(WaveletBasis::parse("db4"), Some(WaveletBasis::Db4));
        assert_eq!(WaveletBasis::parse("morlet"), None);
        assert_eq!(WaveletBasis::parse(""), None);
        assert_eq!(WaveletBasis::default(), WaveletBasis::Haar);
    }

    #[test]
    fn basis_dispatch_matches_free_functions() {
        let x = randmat(4, 64, 17);
        let (m, n, level) = (4, 64, 3);
        assert_eq!(WaveletBasis::Haar.fwd(&x, m, n, level), haar_fwd(&x, m, n, level));
        let c = haar_fwd(&x, m, n, level);
        assert_eq!(WaveletBasis::Haar.inv(&c, m, n, level), haar_inv(&c, m, n, level));
        assert_eq!(WaveletBasis::Db4.fwd(&x, m, n, level), db4::db4_fwd(&x, m, n, level));
        let c = db4::db4_fwd(&x, m, n, level);
        assert_eq!(WaveletBasis::Db4.inv(&c, m, n, level), db4::db4_inv(&c, m, n, level));
    }

    #[test]
    fn every_basis_reconstructs_and_preserves_energy() {
        for b in WaveletBasis::ALL {
            for &(m, n) in &[(1, 8), (3, 32), (5, 96)] {
                let x = randmat(m, n, (m * n) as u64 ^ 0xb5);
                for level in 0..=max_level(n).min(3) {
                    let back = b.inv(&b.fwd(&x, m, n, level), m, n, level);
                    approx_eq_slice(&back, &x, 1e-4);
                    let c = b.fwd(&x, m, n, level);
                    let ex: f64 = x.iter().map(|v| (*v as f64).powi(2)).sum();
                    let ec: f64 = c.iter().map(|v| (*v as f64).powi(2)).sum();
                    assert!(
                        ((ex - ec) / ex).abs() < 1e-5,
                        "{b:?} {m}x{n} level {level}"
                    );
                }
            }
        }
    }

    #[test]
    fn approx_width_is_basis_independent() {
        // The property that makes GWT state shapes identical across
        // bases: every family halves the approximation band per level.
        for b in WaveletBasis::ALL {
            assert_eq!(b.approx_width(160, 2), 40);
            assert_eq!(b.approx_width(64, 0), 64);
            assert_eq!(b.approx_width(64, 6), 1);
        }
    }

    #[test]
    fn basis_check_level_rejects_like_free_function() {
        for b in WaveletBasis::ALL {
            assert!(b.check_level(12, 2).is_ok());
            assert!(b.check_level(12, 3).is_err());
            // Shift-overflow guard holds through the dispatch too.
            assert!(b.check_level(8, 64).is_err());
            assert!(b.check_level(8, usize::MAX).is_err());
        }
    }

    #[test]
    fn unified_lowpass_error_matches_reconstruction_diff() {
        // The single dispatched entry point must equal the
        // reconstruct-and-diff definition it replaced, for every basis
        // (orthonormality: detail energy == reconstruction error).
        let (m, n) = (6, 64);
        let x = randmat(m, n, 23);
        for b in WaveletBasis::ALL {
            for level in 1..=3usize {
                let mut c = b.fwd(&x, m, n, level);
                let q = n >> level;
                for r in 0..m {
                    for j in q..n {
                        c[r * n + j] = 0.0;
                    }
                }
                let back = b.inv(&c, m, n, level);
                let direct: f64 = x
                    .iter()
                    .zip(&back)
                    .map(|(a, v)| ((a - v) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt();
                let unified = b.lowpass_error(&x, m, n, level);
                assert!(
                    (unified - direct).abs() < 1e-4 * (1.0 + direct),
                    "{b:?} level {level}: {unified} vs {direct}"
                );
            }
        }
        // Level 0 keeps everything: zero error.
        assert_eq!(WaveletBasis::Haar.lowpass_error(&x, m, n, 0), 0.0);
    }

    #[test]
    fn lowpass_error_profile_matches_per_level_calls() {
        let (m, n, max) = (4, 96, 4);
        let x = randmat(m, n, 31);
        for b in WaveletBasis::ALL {
            let prof = b.lowpass_error_profile(&x, m, n, max);
            assert_eq!(prof.len(), max);
            for l in 1..=max {
                let single = b.lowpass_error(&x, m, n, l);
                assert!(
                    (prof[l - 1] - single).abs() < 1e-6 * (1.0 + single),
                    "{b:?} level {l}: {} vs {single}",
                    prof[l - 1]
                );
            }
            // Errors are monotone in level (nested detail bands).
            for w in prof.windows(2) {
                assert!(w[1] >= w[0]);
            }
        }
    }

    #[test]
    fn constant_signal_has_zero_details() {
        let x = vec![5.0f32; 64];
        let c = haar_fwd(&x, 1, 64, 4);
        let q = 64 >> 4;
        for (j, v) in c.iter().enumerate().skip(q) {
            assert!(
                v.abs() < 1e-5,
                "detail coeff {j} = {v} should vanish for constant input"
            );
        }
        // Approximation carries all the energy: 5 * sqrt(2^level) each.
        let expect = 5.0 * (16f32).sqrt();
        for v in &c[..q] {
            assert!((v - expect).abs() < 1e-4);
        }
    }
}
